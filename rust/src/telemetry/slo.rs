//! SRE-style multi-window burn-rate SLO evaluation over registry snapshots.
//!
//! A [`SloSpec`] declares the serving objectives MEDEA's paper claims —
//! deadlines met, admission sheds bounded, dispatch p99 bounded, energy per
//! request budgeted, and the design-time atlas still predicting reality
//! (the ledger's drift ratio bounded) — and the [`SloEngine`] judges the live
//! [`RegistrySnapshot`] stream against them. Each objective is scored as a
//! *burn rate*: the fraction of the error budget consumed per unit budget
//! over a rolling window, so `1.0` means "exactly on target" and `2.0`
//! means "burning budget twice as fast as allowed". Two windows are
//! evaluated per objective (fast, e.g. 5 s, and slow, e.g. 60 s) and
//! combined the standard multi-window way: a short spike alone pages nobody,
//! a sustained burn does.
//!
//! States per objective:
//!
//! * `Critical` — fast burn ≥ `critical_burn` *and* slow burn ≥ `warn_burn`
//!   (the burst is real and it has lasted).
//! * `Warn` — both windows ≥ `warn_burn`.
//! * `Ok` — otherwise.
//!
//! A transition into `Critical` (or a fast-window spike at
//! `SPIKE_FACTOR × critical_burn`) arms the flight recorder
//! ([`crate::telemetry::flight`]), which dumps a post-mortem bundle. The
//! engine's latest evaluation is exported as Prometheus gauges
//! (`medea_slo_state`, `medea_slo_burn_rate`) appended to `/metrics`, as
//! JSON on `/slo`, and as a one-line entry in the periodic reporter.
//!
//! All window arithmetic runs on `RegistrySnapshot` deltas keyed by the
//! snapshot's own `uptime` — counters are monotone, so deltas saturate to
//! zero under relaxed-ordering skew rather than underflowing.

use crate::telemetry::flight::FlightRecorder;
use crate::telemetry::hist::{bucket_upper, HistData};
use crate::telemetry::registry::{RegistrySnapshot, TelemetryRegistry, WorkerSnapshot};
use crate::telemetry::trace::TraceRing;
use crate::util::json::{Json, JsonObj};
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Fast-window burn at which a spike fires the flight recorder even before
/// the slow window confirms (a multiple of `critical_burn`).
pub const SPIKE_FACTOR: f64 = 4.0;

/// One objective's verdict, worst first when ordering.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloState {
    Ok,
    Warn,
    Critical,
}

impl SloState {
    pub fn name(self) -> &'static str {
        match self {
            SloState::Ok => "ok",
            SloState::Warn => "warn",
            SloState::Critical => "critical",
        }
    }

    /// Gauge value for the Prometheus export (0 / 1 / 2).
    pub fn code(self) -> u64 {
        match self {
            SloState::Ok => 0,
            SloState::Warn => 1,
            SloState::Critical => 2,
        }
    }
}

/// Declarative serving objectives, evaluated per (platform, workload) pool.
#[derive(Debug, Clone)]
pub struct SloSpec {
    /// Minimum fraction of served requests that must meet their deadline
    /// (error budget = `1 - target`).
    pub deadline_hit_target: f64,
    /// Maximum fraction of admissions (served + shed) that may shed.
    pub shed_ceiling: f64,
    /// Dispatch-latency bound that at least 99% of dispatches must meet
    /// over the window (error budget = 1%).
    pub dispatch_p99_bound: Duration,
    /// Mean simulated energy per served request budget, in µJ
    /// (non-finite disables the objective).
    pub energy_per_request_uj: f64,
    /// Worst-knot atlas drift ratio (realized / modeled dispatch time,
    /// EWMA) the pool may reach before the `atlas_drift` objective burns at
    /// 1.0 (non-finite disables the objective). The ratio is a gauge, not a
    /// budget: both windows see the same instantaneous value, so `Warn`
    /// starts at `warn_burn ×` this bound and `Critical` at
    /// `critical_burn ×` it.
    pub drift_ratio_bound: f64,
    /// Fast burn-rate window (catches bursts).
    pub fast_window: Duration,
    /// Slow burn-rate window (confirms the burst is sustained).
    pub slow_window: Duration,
    /// Burn rate at which an objective degrades to `Warn`.
    pub warn_burn: f64,
    /// Fast-window burn rate at which an objective degrades to `Critical`.
    pub critical_burn: f64,
    /// Minimum events in a window before it can fire (startup noise guard).
    pub min_events: u64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec {
            deadline_hit_target: 0.999,
            shed_ceiling: 0.05,
            dispatch_p99_bound: Duration::from_millis(250),
            energy_per_request_uj: f64::INFINITY,
            drift_ratio_bound: f64::INFINITY,
            fast_window: Duration::from_secs(5),
            slow_window: Duration::from_secs(60),
            warn_burn: 1.0,
            critical_burn: 2.0,
            min_events: 8,
        }
    }
}

/// One retained window sample: merged worker totals at a given uptime.
struct Sample {
    at: Duration,
    totals: WorkerSnapshot,
    shed: u64,
    /// Worst-knot atlas drift ratio at this snapshot (a gauge, not a
    /// counter — see [`crate::telemetry::registry::RegistrySnapshot::drift_ratio`]).
    drift: f64,
}

/// Counter deltas between a window-start sample and the newest one.
struct WindowDelta {
    requests: u64,
    misses: u64,
    shed: u64,
    dispatch: HistData,
    energy_nj: u64,
    /// The later sample's drift gauge (already an EWMA — no differencing).
    drift: f64,
}

impl WindowDelta {
    fn between(earlier: &Sample, later: &Sample) -> WindowDelta {
        WindowDelta {
            requests: later.totals.requests.saturating_sub(earlier.totals.requests),
            misses: later
                .totals
                .deadline_misses
                .saturating_sub(earlier.totals.deadline_misses),
            shed: later.shed.saturating_sub(earlier.shed),
            dispatch: later.totals.dispatch.delta(&earlier.totals.dispatch),
            energy_nj: later
                .totals
                .sim_energy_nj
                .saturating_sub(earlier.totals.sim_energy_nj),
            drift: later.drift,
        }
    }
}

/// One objective's burn rates and derived state at one evaluation.
#[derive(Debug, Clone)]
pub struct ObjectiveStatus {
    /// Stable objective key: `deadline`, `shed`, `dispatch_p99`, `energy`,
    /// `atlas_drift`.
    pub objective: &'static str,
    pub state: SloState,
    pub burn_fast: f64,
    pub burn_slow: f64,
    /// Fast-window burn crossed `SPIKE_FACTOR × critical_burn`.
    pub spike: bool,
}

impl ObjectiveStatus {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("objective", self.objective);
        o.insert("state", self.state.name());
        o.insert("burn_fast", self.burn_fast);
        o.insert("burn_slow", self.burn_slow);
        o.insert("spike", self.spike);
        Json::Obj(o)
    }
}

/// The full result of one evaluation pass.
#[derive(Debug, Clone)]
pub struct SloStatus {
    pub platform: String,
    pub workload: String,
    /// Registry uptime at evaluation.
    pub at: Duration,
    pub objectives: Vec<ObjectiveStatus>,
    /// Objectives that *newly* entered `Critical` on this evaluation.
    pub transitions: Vec<&'static str>,
}

impl SloStatus {
    /// The worst objective state (the pool's headline verdict).
    pub fn worst(&self) -> SloState {
        self.objectives.iter().map(|o| o.state).max().unwrap_or(SloState::Ok)
    }

    /// Whether this evaluation should arm the flight recorder: a fresh
    /// `Critical` transition or a fast-window spike.
    pub fn should_record(&self) -> bool {
        !self.transitions.is_empty() || self.objectives.iter().any(|o| o.spike)
    }

    /// One-line trigger description for the post-mortem bundle.
    pub fn trigger(&self) -> String {
        let firing: Vec<String> = self
            .objectives
            .iter()
            .filter(|o| self.transitions.contains(&o.objective) || o.spike)
            .map(|o| {
                format!(
                    "{} {} (burn {:.2}x/{:.2}x{})",
                    o.objective,
                    o.state.name(),
                    o.burn_fast,
                    o.burn_slow,
                    if o.spike { ", spike" } else { "" }
                )
            })
            .collect();
        if firing.is_empty() {
            format!("manual ({})", self.worst().name())
        } else {
            firing.join("; ")
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("platform", self.platform.as_str());
        o.insert("workload", self.workload.as_str());
        o.insert("uptime_s", self.at.as_secs_f64());
        o.insert("state", self.worst().name());
        o.insert(
            "objectives",
            Json::Arr(self.objectives.iter().map(|obj| obj.to_json()).collect()),
        );
        o.insert(
            "transitions",
            Json::Arr(self.transitions.iter().map(|&t| Json::from(t)).collect()),
        );
        Json::Obj(o)
    }
}

/// Format the reporter's one-line SLO entry.
pub fn slo_line(status: &SloStatus) -> String {
    let mut line = format!(
        "slo[{}/{}]: {}",
        status.platform,
        status.workload,
        status.worst().name()
    );
    for o in &status.objectives {
        let _ = write!(
            line,
            " {}={}({:.2}x/{:.2}x)",
            o.objective,
            o.state.name(),
            o.burn_fast,
            o.burn_slow
        );
    }
    line
}

/// The pure window-arithmetic state machine (no threads, no clocks of its
/// own — time is whatever `RegistrySnapshot::uptime` says).
struct SloEvaluator {
    spec: SloSpec,
    samples: VecDeque<Sample>,
    /// Last observed state per objective, in [`OBJECTIVES`] order.
    last: [SloState; 5],
}

const OBJECTIVES: [&str; 5] = ["deadline", "shed", "dispatch_p99", "energy", "atlas_drift"];

impl SloEvaluator {
    fn new(spec: SloSpec) -> SloEvaluator {
        SloEvaluator { spec, samples: VecDeque::new(), last: [SloState::Ok; 5] }
    }

    /// Fold one snapshot in and judge every objective against both windows.
    fn observe(&mut self, snap: &RegistrySnapshot) -> SloStatus {
        let now = Sample {
            at: snap.uptime,
            totals: snap.totals(),
            shed: snap.total_shed(),
            drift: snap.drift_ratio(),
        };

        // Retain one sample at-or-before the slow-window start so the slow
        // baseline stays resolvable; prune everything older than that.
        let slow_start = now.at.saturating_sub(self.spec.slow_window);
        while self.samples.len() >= 2 && self.samples[1].at <= slow_start {
            self.samples.pop_front();
        }

        let fast = self.window_delta(&now, self.spec.fast_window);
        let slow = self.window_delta(&now, self.spec.slow_window);
        let at = now.at;
        self.samples.push_back(now);

        let mut objectives = Vec::with_capacity(OBJECTIVES.len());
        let mut transitions = Vec::new();
        for (i, name) in OBJECTIVES.iter().enumerate() {
            let burn_fast = self.burn(name, &fast);
            let burn_slow = self.burn(name, &slow);
            let state = if burn_fast >= self.spec.critical_burn && burn_slow >= self.spec.warn_burn
            {
                SloState::Critical
            } else if burn_fast >= self.spec.warn_burn && burn_slow >= self.spec.warn_burn {
                SloState::Warn
            } else {
                SloState::Ok
            };
            if state == SloState::Critical && self.last[i] != SloState::Critical {
                transitions.push(*name);
            }
            self.last[i] = state;
            objectives.push(ObjectiveStatus {
                objective: name,
                state,
                burn_fast,
                burn_slow,
                spike: burn_fast >= SPIKE_FACTOR * self.spec.critical_burn,
            });
        }
        SloStatus {
            platform: snap.platform.clone(),
            workload: snap.workload.clone(),
            at,
            objectives,
            transitions,
        }
    }

    /// Deltas between the newest sample and the youngest retained sample
    /// at-or-before `now - window` (the oldest sample when the pool is
    /// younger than the window).
    fn window_delta(&self, now: &Sample, window: Duration) -> WindowDelta {
        let start = now.at.saturating_sub(window);
        let baseline = self
            .samples
            .iter()
            .rev()
            .find(|s| s.at <= start)
            .or_else(|| self.samples.front());
        match baseline {
            Some(base) => WindowDelta::between(base, now),
            // First-ever observation: nothing to diff against yet.
            None => WindowDelta::between(now, now),
        }
    }

    /// Burn rate for one objective over one window's deltas. Zero when the
    /// window holds fewer than `min_events` relevant events.
    fn burn(&self, objective: &str, d: &WindowDelta) -> f64 {
        const MAX_BURN: f64 = 1e6;
        let spec = &self.spec;
        let burn = match objective {
            "deadline" => {
                if d.requests < spec.min_events {
                    0.0
                } else {
                    let bad = d.misses as f64 / d.requests as f64;
                    bad / (1.0 - spec.deadline_hit_target).max(1e-9)
                }
            }
            "shed" => {
                let admissions = d.requests + d.shed;
                if admissions < spec.min_events {
                    0.0
                } else {
                    let bad = d.shed as f64 / admissions as f64;
                    bad / spec.shed_ceiling.max(1e-9)
                }
            }
            "dispatch_p99" => {
                if d.dispatch.count() < spec.min_events {
                    0.0
                } else {
                    let bound_ns = u64::try_from(spec.dispatch_p99_bound.as_nanos())
                        .unwrap_or(u64::MAX);
                    let over: u64 = d
                        .dispatch
                        .bucket_counts()
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| bucket_upper(i) > bound_ns)
                        .map(|(_, &c)| c)
                        .sum();
                    let bad = over as f64 / d.dispatch.count() as f64;
                    bad / 0.01
                }
            }
            "energy" => {
                if d.requests < spec.min_events || !spec.energy_per_request_uj.is_finite() {
                    0.0
                } else {
                    let mean_uj = d.energy_nj as f64 / 1e3 / d.requests as f64;
                    mean_uj / spec.energy_per_request_uj.max(1e-9)
                }
            }
            "atlas_drift" => {
                // The drift ratio is already an EWMA gauge (0 until the
                // ledger has samples), so no min-events guard and no window
                // differencing: both windows judge the same value.
                if !spec.drift_ratio_bound.is_finite() {
                    0.0
                } else {
                    d.drift / spec.drift_ratio_bound.max(1e-9)
                }
            }
            _ => 0.0,
        };
        burn.min(MAX_BURN)
    }
}

/// Shared SLO engine handle: evaluates on demand (or from a [`SloTicker`]),
/// keeps the latest status for `/slo` and the gauge export, and arms the
/// flight recorder on critical transitions and spikes.
pub struct SloEngine {
    registry: Arc<TelemetryRegistry>,
    trace: Option<Arc<TraceRing>>,
    flight: Option<Arc<FlightRecorder>>,
    evaluator: Mutex<SloEvaluator>,
    latest: Mutex<Option<SloStatus>>,
}

impl SloEngine {
    pub fn new(
        spec: SloSpec,
        registry: Arc<TelemetryRegistry>,
        trace: Option<Arc<TraceRing>>,
        flight: Option<Arc<FlightRecorder>>,
    ) -> Arc<SloEngine> {
        Arc::new(SloEngine {
            registry,
            trace,
            flight,
            evaluator: Mutex::new(SloEvaluator::new(spec)),
            latest: Mutex::new(None),
        })
    }

    /// The flight recorder this engine arms, when one is attached.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    /// Evaluate a fresh registry snapshot now (also what the ticker calls).
    pub fn evaluate_now(&self) -> SloStatus {
        self.observe(&self.registry.snapshot())
    }

    /// Evaluate one explicit snapshot (tests drive synthetic timelines
    /// through this; [`SloEngine::evaluate_now`] is this over a live
    /// snapshot).
    pub fn observe(&self, snap: &RegistrySnapshot) -> SloStatus {
        let status = {
            // lint: allow(no-unwrap): poisoning means an evaluator panicked
            // mid-update; SLO state is then untrustworthy, so propagate.
            let mut ev = self.evaluator.lock().expect("slo evaluator lock poisoned");
            ev.observe(snap)
        };
        if status.should_record() {
            if let Some(flight) = &self.flight {
                let events = self.trace.as_ref().map(|r| r.events()).unwrap_or_default();
                flight.record(&status.trigger(), status.to_json(), snap, &events);
            }
        }
        // lint: allow(no-unwrap): same poisoning rationale as above.
        *self.latest.lock().expect("slo latest lock poisoned") = Some(status.clone());
        status
    }

    /// The most recent evaluation, if any ran yet.
    pub fn latest(&self) -> Option<SloStatus> {
        // lint: allow(no-unwrap): same poisoning rationale as `observe`.
        self.latest.lock().expect("slo latest lock poisoned").clone()
    }

    /// JSON for the `/slo` endpoint: the latest evaluation (running one
    /// first if none has happened yet).
    pub fn status_json(&self) -> Json {
        match self.latest() {
            Some(status) => status.to_json(),
            None => self.evaluate_now().to_json(),
        }
    }

    /// Render `medea_slo_state` / `medea_slo_burn_rate` gauges from the
    /// latest evaluation (empty until one ran). Appended to `/metrics`.
    pub fn render_gauges(&self) -> String {
        let Some(status) = self.latest() else { return String::new() };
        let mut out = String::with_capacity(1024);
        let base = format!(
            "platform=\"{}\",workload=\"{}\"",
            super::exposition::escape_label(&status.platform),
            super::exposition::escape_label(&status.workload)
        );
        let _ = writeln!(
            out,
            "# HELP medea_slo_state Per-objective SLO state (0 = ok, 1 = warn, 2 = critical)."
        );
        let _ = writeln!(out, "# TYPE medea_slo_state gauge");
        for o in &status.objectives {
            let _ = writeln!(
                out,
                "medea_slo_state{{{base},objective=\"{}\"}} {}",
                o.objective,
                o.state.code()
            );
        }
        let _ = writeln!(
            out,
            "# HELP medea_slo_burn_rate Error-budget burn rate per objective and window."
        );
        let _ = writeln!(out, "# TYPE medea_slo_burn_rate gauge");
        for o in &status.objectives {
            let _ = writeln!(
                out,
                "medea_slo_burn_rate{{{base},objective=\"{}\",window=\"fast\"}} {}",
                o.objective,
                o.burn_fast
            );
            let _ = writeln!(
                out,
                "medea_slo_burn_rate{{{base},objective=\"{}\",window=\"slow\"}} {}",
                o.objective,
                o.burn_slow
            );
        }
        out
    }
}

/// Background evaluation cadence; stops (and joins) on drop.
pub struct SloTicker {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl SloTicker {
    /// Evaluate `engine` every `every` (clamped to ≥ 10 ms).
    pub fn start(engine: Arc<SloEngine>, every: Duration) -> SloTicker {
        let every = every.max(Duration::from_millis(10));
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let handle = std::thread::Builder::new()
            .name("medea-slo".into())
            .spawn({
                let stop = stop.clone();
                move || tick_loop(&engine, every, &stop)
            })
            .ok();
        SloTicker { stop, handle }
    }
}

impl Drop for SloTicker {
    fn drop(&mut self) {
        let (lock, cv) = (&self.stop.0, &self.stop.1);
        if let Ok(mut stopped) = lock.lock() {
            *stopped = true;
        }
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn tick_loop(engine: &SloEngine, every: Duration, stop: &(Mutex<bool>, Condvar)) {
    let (lock, cv) = (&stop.0, &stop.1);
    loop {
        {
            let Ok(mut stopped) = lock.lock() else { return };
            while !*stopped {
                let Ok((guard, timeout)) = cv.wait_timeout(stopped, every) else { return };
                stopped = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            if *stopped {
                return;
            }
        }
        let status = engine.evaluate_now();
        if status.worst() != SloState::Ok {
            crate::log_info!("{}", slo_line(&status));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fabricate a snapshot at a synthetic uptime with given totals.
    fn snap(at_s: f64, requests: u64, misses: u64, shed: u64) -> RegistrySnapshot {
        let mut w = WorkerSnapshot {
            requests,
            deadline_misses: misses,
            ..WorkerSnapshot::default()
        };
        for _ in 0..requests.min(64) {
            w.dispatch.record(1_000_000); // 1 ms, comfortably in bound
        }
        RegistrySnapshot {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            uptime: Duration::from_secs_f64(at_s),
            shed_queue_full: shed,
            workers: vec![w],
            ..RegistrySnapshot::default()
        }
    }

    #[test]
    fn healthy_stream_stays_ok() {
        let mut ev = SloEvaluator::new(SloSpec::default());
        for t in 1..=10 {
            let status = ev.observe(&snap(t as f64, t * 100, 0, 0));
            assert_eq!(status.worst(), SloState::Ok, "at t={t}: {status:?}");
            assert!(status.transitions.is_empty());
        }
    }

    #[test]
    fn miss_storm_transitions_to_critical_once() {
        let mut ev = SloEvaluator::new(SloSpec::default());
        for t in 1..=5 {
            ev.observe(&snap(t as f64, t * 200, 0, 0));
        }
        // 400 of the next 500 requests miss their deadline.
        let status = ev.observe(&snap(6.0, 1500, 400, 0));
        assert_eq!(status.worst(), SloState::Critical);
        assert_eq!(status.transitions, vec!["deadline"]);
        let deadline = &status.objectives[0];
        assert_eq!(deadline.objective, "deadline");
        assert!(deadline.burn_fast > 100.0, "burn {}", deadline.burn_fast);
        assert!(deadline.spike);
        // Still critical, but no *new* transition.
        let again = ev.observe(&snap(7.0, 1500, 400, 0));
        assert_eq!(again.worst(), SloState::Critical);
        assert!(again.transitions.is_empty());
    }

    #[test]
    fn brief_spike_without_slow_confirmation_stays_subcritical() {
        // A long healthy history dilutes the slow window below warn while
        // the fast window burns hot: multi-window says not critical.
        let spec = SloSpec { min_events: 1, ..SloSpec::default() };
        let mut ev = SloEvaluator::new(spec);
        for t in 1..=60 {
            ev.observe(&snap(t as f64, t * 10_000, 0, 0));
        }
        // 100 misses in the last 2 s of a 60 s window of ~600k requests:
        // the fast burn runs hot, the slow burn stays well below warn.
        ev.observe(&snap(61.0, 610_000, 0, 0));
        let status = ev.observe(&snap(62.0, 610_100, 100, 0));
        let deadline = &status.objectives[0];
        assert!(deadline.burn_fast >= 1.0, "fast burn {}", deadline.burn_fast);
        assert!(deadline.burn_slow < 1.0, "slow burn {}", deadline.burn_slow);
        assert_eq!(deadline.state, SloState::Ok);
    }

    #[test]
    fn shed_storm_fires_the_shed_objective() {
        let mut ev = SloEvaluator::new(SloSpec::default());
        ev.observe(&snap(1.0, 100, 0, 0));
        let status = ev.observe(&snap(2.0, 150, 0, 500));
        let shed = status
            .objectives
            .iter()
            .find(|o| o.objective == "shed")
            .expect("shed objective present");
        assert_eq!(shed.state, SloState::Critical);
        assert!(status.transitions.contains(&"shed"));
    }

    #[test]
    fn min_events_guards_startup_noise() {
        let mut ev = SloEvaluator::new(SloSpec::default());
        ev.observe(&snap(0.1, 0, 0, 0));
        // 2 requests, 1 miss: catastrophic ratio, but below min_events.
        let status = ev.observe(&snap(0.2, 2, 1, 0));
        assert_eq!(status.worst(), SloState::Ok);
    }

    #[test]
    fn status_json_and_line_render() {
        let mut ev = SloEvaluator::new(SloSpec::default());
        ev.observe(&snap(1.0, 100, 0, 0));
        let status = ev.observe(&snap(2.0, 300, 150, 0));
        let j = status.to_json();
        assert_eq!(j.get("state").and_then(|v| v.as_str()), Some("critical"));
        let objectives = j.get("objectives").and_then(|v| v.as_arr()).expect("objectives");
        assert_eq!(objectives.len(), 5);
        assert_eq!(
            objectives[0].get("objective").and_then(|v| v.as_str()),
            Some("deadline")
        );
        let line = slo_line(&status);
        assert!(line.starts_with("slo[heeptimize/tsd-core]: critical"), "{line}");
        assert!(line.contains("deadline=critical("), "{line}");
        assert!(status.trigger().contains("deadline"), "{}", status.trigger());
    }

    #[test]
    fn atlas_drift_objective_fires_only_when_bounded() {
        use crate::telemetry::ledger::{LedgerEntrySnapshot, LedgerSnapshot};
        let with_drift = |at_s: f64, requests: u64, drift: f64| {
            let mut s = snap(at_s, requests, 0, 0);
            s.ledger = Some(LedgerSnapshot {
                entries: vec![LedgerEntrySnapshot {
                    knot_drift: vec![drift, drift / 2.0],
                    ..LedgerEntrySnapshot::default()
                }],
                unattributed: 0,
            });
            s
        };
        // Unbounded (default spec): even wild drift never burns.
        let mut ev = SloEvaluator::new(SloSpec::default());
        let status = ev.observe(&with_drift(1.0, 100, 4.0));
        let drift = status
            .objectives
            .iter()
            .find(|o| o.objective == "atlas_drift")
            .expect("atlas_drift objective present");
        assert_eq!((drift.state, drift.burn_fast), (SloState::Ok, 0.0));
        // Bounded: a healthy ratio stays Ok, a drifting one goes Critical
        // (same gauge in both windows, so the transition is immediate).
        let spec = SloSpec { drift_ratio_bound: 1.5, ..SloSpec::default() };
        let mut ev = SloEvaluator::new(spec);
        let status = ev.observe(&with_drift(1.0, 100, 0.4));
        let drift = status.objectives.last().expect("objectives populated");
        assert_eq!(drift.objective, "atlas_drift");
        assert_eq!(drift.state, SloState::Ok);
        let status = ev.observe(&with_drift(2.0, 200, 3.3));
        let drift = status.objectives.last().expect("objectives populated");
        assert_eq!(drift.state, SloState::Critical);
        assert!((drift.burn_fast - 2.2).abs() < 1e-9, "burn {}", drift.burn_fast);
        assert_eq!(drift.burn_fast, drift.burn_slow);
        assert_eq!(status.transitions, vec!["atlas_drift"]);
        assert!(status.should_record());
        assert!(status.trigger().contains("atlas_drift"), "{}", status.trigger());
        // A snapshot with no ledger reads as zero drift and recovers.
        let status = ev.observe(&snap(3.0, 300, 0, 0));
        assert_eq!(status.objectives.last().expect("objectives").state, SloState::Ok);
    }

    #[test]
    fn engine_latest_and_gauges_agree() {
        let registry = Arc::new(TelemetryRegistry::new("heeptimize", "tsd-core", 1));
        let engine = SloEngine::new(SloSpec::default(), registry, None, None);
        assert!(engine.latest().is_none());
        assert_eq!(engine.render_gauges(), "");
        engine.observe(&snap(1.0, 100, 0, 0));
        engine.observe(&snap(2.0, 300, 150, 0));
        let latest = engine.latest().expect("latest status");
        assert_eq!(latest.worst(), SloState::Critical);
        let gauges = engine.render_gauges();
        assert!(
            gauges.contains("medea_slo_state{platform=\"heeptimize\",workload=\"tsd-core\",objective=\"deadline\"} 2"),
            "{gauges}"
        );
        assert!(gauges.contains("window=\"fast\""), "{gauges}");
        // Every non-comment line parses like the main exposition.
        for line in gauges.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert!(line.starts_with("medea_slo_"), "bad line: {line}");
            let (_, value) = line.rsplit_once(' ').expect("value separator");
            assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
        }
        let j = engine.status_json();
        assert_eq!(j.get("state").and_then(|v| v.as_str()), Some("critical"));
    }
}
