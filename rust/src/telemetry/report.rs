//! A periodic one-line telemetry reporter.
//!
//! Every interval, snapshot the registry, diff against the previous
//! snapshot, and log one INFO line through [`crate::util::log`]: request
//! rate, cumulative p50/p99 host latency, shed and steal rates, mean batch
//! size, and mean energy per request over the interval — plus, when the
//! pool carries an energy ledger, the interval's busiest PE and the worst
//! atlas drift ratio. Enable with `MEDEA_LOG=info` (see
//! [`crate::util::log::init_from_env`]).

use crate::telemetry::ledger::LedgerSnapshot;
use crate::telemetry::registry::{RegistrySnapshot, TelemetryRegistry};
use crate::telemetry::slo::{slo_line, SloEngine};
use std::fmt::Write as _;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Background reporter thread; stops (and joins) on drop.
pub struct Reporter {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl Reporter {
    /// Log one summary line every `every` (clamped to ≥ 10 ms).
    pub fn start(registry: Arc<TelemetryRegistry>, every: Duration) -> Reporter {
        Self::start_with_slo(registry, every, None)
    }

    /// [`Reporter::start`], additionally logging the latest SLO verdict
    /// (one `slo[...]` line per interval) when an engine is attached.
    pub fn start_with_slo(
        registry: Arc<TelemetryRegistry>,
        every: Duration,
        slo: Option<Arc<SloEngine>>,
    ) -> Reporter {
        let every = every.max(Duration::from_millis(10));
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let handle = std::thread::Builder::new()
            .name("medea-telemetry-report".into())
            .spawn({
                let stop = stop.clone();
                move || report_loop(&registry, slo.as_deref(), every, &stop)
            })
            .ok();
        Reporter { stop, handle }
    }
}

impl Drop for Reporter {
    fn drop(&mut self) {
        let (lock, cv) = (&self.stop.0, &self.stop.1);
        if let Ok(mut stopped) = lock.lock() {
            *stopped = true;
        }
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn report_loop(
    registry: &TelemetryRegistry,
    slo: Option<&SloEngine>,
    every: Duration,
    stop: &(Mutex<bool>, Condvar),
) {
    let (lock, cv) = (&stop.0, &stop.1);
    let mut prev = registry.snapshot();
    let mut prev_at = Instant::now();
    loop {
        {
            let Ok(mut stopped) = lock.lock() else { return };
            while !*stopped {
                let Ok((guard, timeout)) = cv.wait_timeout(stopped, every) else { return };
                stopped = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            if *stopped {
                return;
            }
        }
        let snap = registry.snapshot();
        let now = Instant::now();
        crate::log_info!("{}", report_line(&prev, &snap, now.duration_since(prev_at)));
        if let Some(engine) = slo {
            if let Some(status) = engine.latest() {
                crate::log_info!("{}", slo_line(&status));
            }
        }
        prev = snap;
        prev_at = now;
    }
}

/// Format one interval summary (`prev` → `now` over `dt`). Public so tests
/// (and other frontends) can exercise the format without a thread.
pub fn report_line(prev: &RegistrySnapshot, now: &RegistrySnapshot, dt: Duration) -> String {
    let p = prev.totals();
    let t = now.totals();
    let dt_s = dt.as_secs_f64().max(1e-9);
    let d_req = t.requests.saturating_sub(p.requests);
    let d_shed = now.total_shed().saturating_sub(prev.total_shed());
    let d_steal = t.steals.saturating_sub(p.steals);
    let d_disp = t.dispatches().saturating_sub(p.dispatches());
    let d_energy_nj = t.sim_energy_nj.saturating_sub(p.sim_energy_nj);
    let mean_batch = if d_disp > 0 { d_req as f64 / d_disp as f64 } else { 0.0 };
    let uj_per_req = if d_req > 0 { d_energy_nj as f64 / 1e3 / d_req as f64 } else { 0.0 };
    let mut line = format!(
        "telemetry[{}/{}]: {:.1} req/s p50={:?} p99={:?} shed/s={:.1} steal/s={:.2} \
         mean_batch={:.2} energy/req={:.1} uJ",
        now.platform,
        now.workload,
        d_req as f64 / dt_s,
        Duration::from_nanos(t.host.percentile(50.0)),
        Duration::from_nanos(t.host.percentile(99.0)),
        d_shed as f64 / dt_s,
        d_steal as f64 / dt_s,
        mean_batch,
        uj_per_req,
    );
    if let Some(ledger) = &now.ledger {
        let fresh = LedgerSnapshot::default();
        let baseline = prev.ledger.as_ref().unwrap_or(&fresh);
        if let Some((pe, share)) = ledger.top_pe_since(baseline) {
            let _ = write!(line, " top_pe={pe}({:.0}%)", share * 100.0);
        }
        let drift = ledger.max_drift();
        if drift > 0.0 {
            let _ = write!(line, " drift={drift:.2}x");
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::queue::Rejection;

    #[test]
    fn report_line_diffs_intervals() {
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 1);
        let before = reg.snapshot();
        let w = reg.worker(0);
        for _ in 0..10 {
            w.record(false, true, 100e-6, 0.01, Duration::from_millis(2));
        }
        w.record_batch(8);
        w.record_batch(2);
        reg.record_shed(&Rejection::QueueFull { capacity: 4 });
        let after = reg.snapshot();
        let line = report_line(&before, &after, Duration::from_secs(2));
        assert!(line.contains("5.0 req/s"), "{line}");
        assert!(line.contains("shed/s=0.5"), "{line}");
        assert!(line.contains("mean_batch=5.00"), "{line}");
        assert!(line.contains("energy/req=100.0 uJ"), "{line}");
        assert!(line.contains("telemetry[heeptimize/tsd-core]"), "{line}");
    }

    #[test]
    fn report_line_appends_top_pe_and_drift_from_the_ledger() {
        use crate::manager::schedule::Decision;
        use crate::platform::PeId;
        use crate::telemetry::ledger::{EnergyLedger, LedgerEntrySpec};
        use crate::tiling::modes::TilingMode;
        use crate::util::units::{Energy, Time};
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 1);
        reg.install_ledger(EnergyLedger::new(1, &[LedgerEntrySpec {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            pe_labels: vec!["cpu".into(), "cgra".into()],
            vf_labels: vec!["0.90V@250MHz".into()],
            knot_deadlines: vec![Time::from_ms(50.0)],
        }]));
        let before = reg.snapshot();
        let decisions = [Decision {
            kernel: 0,
            pe: PeId(1),
            vf_idx: 0,
            mode: TilingMode::SingleBuffer,
            time: Time::from_us(300.0),
            energy: Energy::from_uj(4.0),
        }];
        reg.ledger().expect("ledger installed").record_dispatch(
            0,
            0,
            Time::from_ms(50.0),
            &decisions,
            1,
            Duration::from_millis(25),
            Time::from_ms(10.0),
        );
        reg.worker(0).record(false, true, 4e-6, 3e-4, Duration::from_millis(1));
        let after = reg.snapshot();
        let line = report_line(&before, &after, Duration::from_secs(1));
        assert!(line.contains("top_pe=heeptimize/tsd-core:cgra(100%)"), "{line}");
        assert!(line.contains("drift=2.50x"), "{line}");
        // Without a ledger the line keeps its original shape.
        let bare = TelemetryRegistry::new("heeptimize", "tsd-core", 1);
        let line = report_line(&bare.snapshot(), &bare.snapshot(), Duration::from_secs(1));
        assert!(!line.contains("top_pe"), "{line}");
        assert!(!line.contains("drift="), "{line}");
    }

    #[test]
    fn reporter_thread_starts_and_stops() {
        let reg = Arc::new(TelemetryRegistry::new("heeptimize", "tsd-core", 1));
        let reporter = Reporter::start(reg.clone(), Duration::from_millis(10));
        reg.worker(0).record(false, true, 1e-6, 0.0, Duration::from_micros(100));
        std::thread::sleep(Duration::from_millis(30));
        drop(reporter); // must not hang
    }
}
