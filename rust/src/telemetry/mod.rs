//! Live observability for the serving layers: lock-free metrics, Prometheus
//! exposition, and dispatch-event tracing.
//!
//! MEDEA's claims — energy reduction while meeting every timing constraint —
//! were only checkable at shutdown before this module: per-worker
//! [`crate::coordinator::Metrics`] merged once after the pool drained. Here
//! both pools publish continuously instead:
//!
//! * [`hist`] — fixed-bucket log-linear histograms: a wait-free atomic
//!   recording form and a mergeable snapshot form sharing one bucket layout,
//!   so live and shutdown percentiles are the same arithmetic.
//! * [`registry`] — the per-pool [`TelemetryRegistry`]: one
//!   [`registry::WorkerShard`] of atomic counters + histograms per worker
//!   (queue wait, dispatch latency, head laxity, batch size, per-request
//!   energy), admission-side shed counters, and whole-registry snapshots.
//!   `ServeMetrics` is now *derived from* this registry — there is no
//!   separate shutdown bookkeeping path.
//! * [`exposition`] — Prometheus text format 0.0.4 over a minimal blocking
//!   `std::net` responder (`serve --metrics-addr`) that also routes
//!   `/healthz`, `/readyz` (pool [`ReadinessProbe`]), and `/slo`; plus the
//!   bounded [`scrape`] / [`http_get`] clients behind `medea scrape` and
//!   `medea health`.
//! * [`trace`] — a bounded lock-free ring of typed dispatch events
//!   (enqueue, shed, steal, batch-form, dispatch, retire) with request ids
//!   and monotonic timestamps, dumpable as chrome://tracing JSON
//!   (`serve --trace-out`).
//! * [`report`] — a periodic reporter logging a one-line rates summary
//!   through [`crate::util::log`] (`serve --report-every-s`).
//! * [`slo`] — the declarative [`SloSpec`] judged against registry deltas
//!   over rolling fast/slow windows (SRE multi-window burn rates), exported
//!   as `Ok`/`Warn`/`Critical` gauges, `/slo` JSON, and a reporter line
//!   (`serve --slo-*`).
//! * [`flight`] — the anomaly-triggered flight recorder: on a `Critical`
//!   transition or burn-rate spike, one rate-limited post-mortem bundle
//!   (registry snapshot + trace tail + the firing evaluation) lands in a
//!   bounded `--postmortem-dir`.
//! * [`ledger`] — the kernel-level energy attribution ledger: per-dispatch
//!   decomposition of the resolved schedule into per-(PE, V-F) energy and
//!   busy-time tables plus per-knot dispatch counters, with a per-knot
//!   EWMA **atlas drift detector** (realized vs. modeled dispatch time)
//!   feeding the SLO engine's `atlas_drift` objective and the
//!   `medea energy-report` tables.
//!
//! Everything is `std`-only and allocation-free on the hot path: counters
//! are relaxed atomics, histograms are fixed tables, the trace ring is
//! seqlock-published fixed slots.

// Telemetry rides the serving hot path: a panicking `.unwrap()` here takes
// a pool worker down with it. Carry errors or degrade instead (`.expect`
// with an invariant message is allowed for real invariants).
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod exposition;
pub mod flight;
pub mod hist;
pub mod ledger;
pub mod registry;
pub mod report;
pub mod slo;
pub mod trace;

pub use exposition::{
    http_get, render_prometheus, scrape, scrape_with, MetricsServer, Readiness, ReadinessProbe,
};
pub use flight::{FlightConfig, FlightRecorder};
pub use hist::HistData;
pub use ledger::{
    ledger_from_prometheus, render_energy_report, EnergyLedger, LedgerEntrySnapshot,
    LedgerEntrySpec, LedgerSnapshot,
};
pub use registry::{RegistrySnapshot, TelemetryRegistry, WorkerShard, WorkerSnapshot};
pub use report::{report_line, Reporter};
pub use slo::{slo_line, SloEngine, SloSpec, SloState, SloStatus, SloTicker};
pub use trace::{TraceEvent, TraceEventKind, TraceRing};

/// Pool-side telemetry knobs (embedded in `PoolConfig` / `FleetPoolConfig`).
///
/// The metrics registry itself has no switch: it *is* the pool's metrics
/// path, on whether or not anyone scrapes it.
#[derive(Debug, Clone, Default)]
pub struct TelemetryConfig {
    /// Capacity (in events) of the dispatch-event trace ring; 0 disables
    /// tracing entirely (no ring is allocated, no events are recorded).
    pub trace_events: usize,
}
