//! A bounded lock-free ring of typed dispatch events.
//!
//! Both pools thread a request id from admission through dequeue to retire;
//! each step drops one fixed-size event into the ring — a few relaxed atomic
//! stores, no lock, no allocation. The ring overwrites oldest-first, so a
//! long-running pool keeps the most recent `capacity` events.
//!
//! Publication uses a per-slot sequence word (seqlock style): the writer
//! zeroes it, writes the payload with `Release` stores, then stores the new
//! nonzero sequence with `Release`; a reader that sees the same nonzero
//! sequence before and after its `Acquire` payload loads observed a
//! consistent event, and drops the slot otherwise. The payload accesses
//! themselves carry `Release`/`Acquire` (not `Relaxed`): that is what makes
//! the zeroed sequence word visible to any reader that observes a torn
//! payload value, so the re-check catches it — see the `ordering:` notes in
//! [`TraceRing::record`] and [`TraceRing::events`]. Reads are best-effort by
//! design — tracing must never stall the dispatch path.
//!
//! [`TraceRing::to_chrome_json`] renders the surviving events as a
//! chrome://tracing (about://tracing, Perfetto) loadable JSON document with
//! one track per worker.

use crate::util::json::{Json, JsonObj};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// What happened to a request at one point of the dispatch path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum TraceEventKind {
    /// Admitted into a shard's EDF queue (`arg` = deadline, µs).
    Enqueue = 0,
    /// Shed at admission or dispatch (`arg` = rejection code).
    Shed = 1,
    /// Group lifted from a sibling shard by an idle worker (`arg` = size).
    Steal = 2,
    /// Multiple queued requests coalesced into one dispatch (`arg` = size).
    BatchForm = 3,
    /// Group handed to the execution path (`arg` = size).
    Dispatch = 4,
    /// Request finished and its reply was readied (`arg` = deadline met).
    Retire = 5,
    /// One kernel's execution slice within a dispatch (`arg` = duration in
    /// ns; kernel index, PE and V-F point ride in [`TraceEvent::extra`]).
    /// Rendered as a real duration slice on a per-PE track — the
    /// paper-style Gantt view of live traffic.
    KernelSpan = 6,
}

impl TraceEventKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceEventKind::Enqueue => "enqueue",
            TraceEventKind::Shed => "shed",
            TraceEventKind::Steal => "steal",
            TraceEventKind::BatchForm => "batch_form",
            TraceEventKind::Dispatch => "dispatch",
            TraceEventKind::Retire => "retire",
            TraceEventKind::KernelSpan => "kernel",
        }
    }

    fn from_u64(v: u64) -> Option<TraceEventKind> {
        match v {
            0 => Some(TraceEventKind::Enqueue),
            1 => Some(TraceEventKind::Shed),
            2 => Some(TraceEventKind::Steal),
            3 => Some(TraceEventKind::BatchForm),
            4 => Some(TraceEventKind::Dispatch),
            5 => Some(TraceEventKind::Retire),
            6 => Some(TraceEventKind::KernelSpan),
            _ => None,
        }
    }
}

/// Pack a kernel span's coordinates into the meta word's free high bits
/// (bits 40..64): kernel index (10 bits), PE (6), V-F point (8). Larger
/// values clamp — a >1023-kernel workload still traces, with the overflow
/// kernels labeled `k1023`.
fn pack_span(kernel: usize, pe: usize, vf: usize) -> u64 {
    (kernel.min(0x3ff) as u64) | (pe.min(0x3f) as u64) << 10 | (vf.min(0xff) as u64) << 16
}

/// Rejection code carried in a [`TraceEventKind::Shed`] event's `arg`
/// (mirrors [`crate::serve::queue::Rejection::code`]).
pub fn shed_reason_name(code: u64) -> &'static str {
    match code {
        0 => "below_floor",
        1 => "below_energy_floor",
        2 => "unknown_entry",
        3 => "queue_full",
        4 => "shutting_down",
        _ => "unknown",
    }
}

/// One decoded trace event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Global record ordinal (0-based; ties broken by this in the dump).
    pub seq: u64,
    pub kind: TraceEventKind,
    /// Worker (shard) index; 0 for admission-side events.
    pub worker: u32,
    /// Nanoseconds since the ring was created (monotonic clock).
    pub ts_ns: u64,
    /// Request id from [`crate::telemetry::TelemetryRegistry`]; for group
    /// events, the id of the group head.
    pub req: u64,
    /// Kind-specific payload (see [`TraceEventKind`] docs).
    pub arg: u64,
    /// High meta bits — zero except for [`TraceEventKind::KernelSpan`],
    /// which packs (kernel, pe, vf) here (see the `span_*` accessors).
    pub extra: u32,
}

impl TraceEvent {
    /// Kernel index of a [`TraceEventKind::KernelSpan`] event.
    pub fn span_kernel(&self) -> usize {
        (self.extra & 0x3ff) as usize
    }

    /// PE index (the Gantt track) of a kernel span event.
    pub fn span_pe(&self) -> usize {
        ((self.extra >> 10) & 0x3f) as usize
    }

    /// V-F point index of a kernel span event.
    pub fn span_vf(&self) -> usize {
        ((self.extra >> 16) & 0xff) as usize
    }
}

#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    ts_ns: AtomicU64,
    meta: AtomicU64,
    req: AtomicU64,
    arg: AtomicU64,
}

/// The bounded ring. `record` is wait-free; `events` is a best-effort scan.
pub struct TraceRing {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
    epoch: Instant,
}

impl TraceRing {
    /// `capacity` is clamped to at least 16 events.
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(16);
        TraceRing {
            slots: (0..capacity).map(|_| Slot::default()).collect(),
            cursor: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (recorded − capacity ≈ overwritten).
    pub fn recorded(&self) -> u64 {
        // ordering: monotone statistic — no payload is read through this
        // value, so no synchronization is needed.
        self.cursor.load(Ordering::Relaxed)
    }

    /// Nanoseconds since this ring's epoch — the timebase every event's
    /// `ts_ns` is expressed in. Callers recording spans with explicit start
    /// times ([`TraceRing::record_kernel_span`]) anchor against this.
    pub fn now_ns(&self) -> u64 {
        u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }

    pub fn record(&self, kind: TraceEventKind, worker: u32, req: u64, arg: u64) {
        self.write_slot(kind as u64 | (u64::from(worker) << 8), self.now_ns(), req, arg);
    }

    /// Record one per-kernel execution span within a dispatch: `start_ns`
    /// in this ring's timebase ([`TraceRing::now_ns`]), `dur_ns` the span
    /// length (also the event `arg`), with (kernel, pe, vf) packed into the
    /// meta word so the chrome dump can place the slice on a per-PE track.
    pub fn record_kernel_span(
        &self,
        worker: u32,
        req: u64,
        kernel: usize,
        pe: usize,
        vf: usize,
        start_ns: u64,
        dur_ns: u64,
    ) {
        let meta = TraceEventKind::KernelSpan as u64
            | (u64::from(worker) << 8)
            | (pack_span(kernel, pe, vf) << 40);
        self.write_slot(meta, start_ns, req, dur_ns);
    }

    fn write_slot(&self, meta: u64, ts: u64, req: u64, arg: u64) {
        // ordering: the cursor is only a ticket dispenser; slot publication
        // below carries all reader-visible ordering.
        let n = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        // Invalidate, write payload, publish: see the module docs.
        //
        // ordering: seqlock write side. The zero-store needs no ordering of
        // its own (Relaxed): each payload store below is Release, which
        // keeps the invalidation ordered before the payload value any
        // reader can observe — a reader that Acquire-loads a torn payload
        // value synchronizes with that store, sees seq = 0 (or a later
        // seq) on its re-check, and discards the slot. (The earlier scheme
        // — Release zero-store, Relaxed payload stores — did NOT give this:
        // a Release store only orders *prior* accesses, so the payload
        // stores could become visible before the invalidation and a reader
        // could pass both seq checks around a torn read.) The final
        // nonzero-seq store is Release so a reader whose first seq load
        // acquires it also observes the complete payload.
        slot.seq.store(0, Ordering::Relaxed);
        slot.ts_ns.store(ts, Ordering::Release);
        slot.meta.store(meta, Ordering::Release);
        slot.req.store(req, Ordering::Release);
        slot.arg.store(arg, Ordering::Release);
        slot.seq.store(n + 1, Ordering::Release);
    }

    /// Decode every currently-consistent slot, sorted by timestamp.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            // ordering: seqlock read side — see `record`. Acquiring the
            // first seq load pairs with the writer's publishing store: a
            // nonzero value here means the matching payload is visible.
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 {
                continue;
            }
            // ordering: Acquire payload loads pair with the writer's
            // Release payload stores; observing any in-progress value makes
            // that writer's seq = 0 invalidation visible to the re-check
            // below, which then fails s1 == s2. They also pin the re-check:
            // an Acquire load forbids later operations from hoisting above
            // it, so s2 cannot be read before the payload.
            let ts_ns = slot.ts_ns.load(Ordering::Acquire);
            let meta = slot.meta.load(Ordering::Acquire);
            let req = slot.req.load(Ordering::Acquire);
            let arg = slot.arg.load(Ordering::Acquire);
            // ordering: re-check — see the notes on the loads above.
            let s2 = slot.seq.load(Ordering::Acquire);
            if s1 != s2 {
                continue; // torn by a concurrent wrap-around write
            }
            let Some(kind) = TraceEventKind::from_u64(meta & 0xff) else {
                continue;
            };
            out.push(TraceEvent {
                seq: s1 - 1,
                kind,
                worker: (meta >> 8) as u32,
                ts_ns,
                req,
                arg,
                extra: (meta >> 40) as u32,
            });
        }
        out.sort_by_key(|e| (e.ts_ns, e.seq));
        out
    }

    /// Render as a chrome://tracing JSON document. Dispatch-path events are
    /// instants on per-worker tracks (`pid` 1); kernel spans are duration
    /// slices on per-PE tracks (`pid` 2) — the paper-style Gantt view.
    /// Timestamps and durations are in µs.
    pub fn to_chrome_json(&self) -> String {
        let decoded = self.events();
        let has_spans = decoded.iter().any(|e| e.kind == TraceEventKind::KernelSpan);
        let mut events: Vec<Json> = Vec::with_capacity(decoded.len() + 1);
        if has_spans {
            // Label the span process so the per-PE Gantt reads as "PEs".
            let mut args = JsonObj::new();
            args.insert("name", "PEs");
            let mut m = JsonObj::new();
            m.insert("name", "process_name");
            m.insert("ph", "M");
            m.insert("pid", 2u64);
            m.insert("args", args);
            events.push(Json::Obj(m));
        }
        for e in decoded {
            let mut args = JsonObj::new();
            args.insert("req", e.req);
            let mut o = JsonObj::new();
            if e.kind == TraceEventKind::KernelSpan {
                args.insert("kernel", e.span_kernel() as u64);
                args.insert("vf", e.span_vf() as u64);
                args.insert("worker", u64::from(e.worker));
                let name = format!("k{}", e.span_kernel());
                o.insert("name", name.as_str());
                o.insert("cat", "medea");
                o.insert("ph", "X");
                o.insert("pid", 2u64);
                o.insert("tid", e.span_pe() as u64);
                o.insert("ts", e.ts_ns as f64 / 1e3);
                o.insert("dur", e.arg as f64 / 1e3);
            } else {
                match e.kind {
                    TraceEventKind::Enqueue => args.insert("deadline_us", e.arg),
                    TraceEventKind::Shed => args.insert("reason", shed_reason_name(e.arg)),
                    TraceEventKind::Retire => args.insert("met", e.arg == 1),
                    TraceEventKind::Steal
                    | TraceEventKind::BatchForm
                    | TraceEventKind::Dispatch
                    | TraceEventKind::KernelSpan => args.insert("size", e.arg),
                }
                o.insert("name", e.kind.name());
                o.insert("cat", "medea");
                o.insert("ph", "i");
                o.insert("s", "t");
                o.insert("pid", 1u64);
                o.insert("tid", u64::from(e.worker));
                o.insert("ts", e.ts_ns as f64 / 1e3);
            }
            o.insert("args", args);
            events.push(Json::Obj(o));
        }
        let mut root = JsonObj::new();
        root.insert("traceEvents", Json::Arr(events));
        root.insert("displayTimeUnit", "ms");
        Json::Obj(root).to_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_decode_in_order() {
        let ring = TraceRing::new(64);
        ring.record(TraceEventKind::Enqueue, 0, 1, 100_000);
        ring.record(TraceEventKind::Dispatch, 1, 1, 1);
        ring.record(TraceEventKind::Retire, 1, 1, 1);
        let events = ring.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].kind, TraceEventKind::Enqueue);
        assert_eq!(events[2].kind, TraceEventKind::Retire);
        assert_eq!(events[1].worker, 1);
        assert!(events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
        assert_eq!(ring.recorded(), 3);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let ring = TraceRing::new(16);
        for i in 0..40u64 {
            ring.record(TraceEventKind::Retire, 0, i, 1);
        }
        let events = ring.events();
        assert_eq!(events.len(), 16);
        // Only the newest capacity-many survive.
        assert!(events.iter().all(|e| e.req >= 24));
        assert_eq!(ring.recorded(), 40);
    }

    #[test]
    fn chrome_dump_parses_as_json() {
        let ring = TraceRing::new(32);
        ring.record(TraceEventKind::Enqueue, 0, 7, 250_000);
        ring.record(TraceEventKind::Shed, 0, 8, 3);
        ring.record(TraceEventKind::BatchForm, 2, 7, 4);
        let doc = ring.to_chrome_json();
        let v = crate::util::json::parse(&doc).expect("dump parses");
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        assert_eq!(evs.len(), 3);
        for e in evs {
            assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("i"));
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        }
        // The shed event carries its decoded reason.
        let shed = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("shed"))
            .expect("shed event");
        let reason = shed.get("args").and_then(|a| a.get("reason")).and_then(|r| r.as_str());
        assert_eq!(reason, Some("queue_full"));
    }

    #[test]
    fn kernel_spans_decode_and_render_as_slices() {
        let ring = TraceRing::new(32);
        let t0 = ring.now_ns();
        ring.record(TraceEventKind::Dispatch, 1, 7, 2);
        ring.record_kernel_span(1, 7, 0, 2, 5, t0, 1_000);
        ring.record_kernel_span(1, 7, 1, 0, 3, t0 + 1_000, 2_000);
        let spans: Vec<TraceEvent> = ring
            .events()
            .into_iter()
            .filter(|e| e.kind == TraceEventKind::KernelSpan)
            .collect();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].span_kernel(), 0);
        assert_eq!(spans[0].span_pe(), 2);
        assert_eq!(spans[0].span_vf(), 5);
        assert_eq!(spans[0].worker, 1);
        assert_eq!(spans[0].arg, 1_000);
        assert_eq!(spans[1].span_kernel(), 1);
        assert_eq!(spans[1].ts_ns, t0 + 1_000);
        // Oversized coordinates clamp instead of bleeding across fields.
        ring.record_kernel_span(1, 8, 5_000, 99, 300, t0, 10);
        let clamped = ring
            .events()
            .into_iter()
            .find(|e| e.req == 8)
            .expect("clamped span recorded");
        assert_eq!(clamped.span_kernel(), 0x3ff);
        assert_eq!(clamped.span_pe(), 0x3f);
        assert_eq!(clamped.span_vf(), 0xff);
        let doc = ring.to_chrome_json();
        let v = crate::util::json::parse(&doc).expect("dump parses");
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        let slices: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(slices.len(), 3);
        let first = slices
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("k0"))
            .expect("k0 slice");
        assert_eq!(first.get("pid").and_then(|p| p.as_u64()), Some(2));
        assert_eq!(first.get("tid").and_then(|t| t.as_u64()), Some(2));
        assert_eq!(first.get("dur").and_then(|d| d.as_f64()), Some(1.0));
        assert_eq!(
            first.get("args").and_then(|a| a.get("vf")).and_then(|x| x.as_u64()),
            Some(5)
        );
        // The span process carries its metadata label.
        assert!(evs
            .iter()
            .any(|e| e.get("name").and_then(|n| n.as_str()) == Some("process_name")));
        // Dispatch-path instants are untouched by the span track.
        assert!(evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("i"))
            .all(|e| e.get("pid").and_then(|p| p.as_u64()) == Some(1)));
    }

    #[test]
    fn concurrent_writers_never_corrupt_readers() {
        use std::sync::Arc;
        // Under Miri the interpreter costs ~3 orders of magnitude; keep the
        // shape (4 writers, concurrent scans, several wrap-arounds of the
        // 128-slot ring) but shrink the volume so the job finishes.
        const WRITES: u64 = if cfg!(miri) { 200 } else { 2_000 };
        const SCANS: usize = if cfg!(miri) { 8 } else { 50 };
        let ring = Arc::new(TraceRing::new(128));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let ring = ring.clone();
                std::thread::spawn(move || {
                    for i in 0..WRITES {
                        ring.record(TraceEventKind::Dispatch, w, i, 1);
                    }
                })
            })
            .collect();
        for _ in 0..SCANS {
            for e in ring.events() {
                assert_eq!(e.kind, TraceEventKind::Dispatch);
                assert!(e.worker < 4 && e.arg == 1);
            }
        }
        for t in writers {
            t.join().expect("writer thread");
        }
        assert_eq!(ring.recorded(), 4 * WRITES);
        assert_eq!(ring.events().len(), 128);
    }
}
