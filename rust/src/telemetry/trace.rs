//! A bounded lock-free ring of typed dispatch events.
//!
//! Both pools thread a request id from admission through dequeue to retire;
//! each step drops one fixed-size event into the ring — a few relaxed atomic
//! stores, no lock, no allocation. The ring overwrites oldest-first, so a
//! long-running pool keeps the most recent `capacity` events.
//!
//! Publication uses a per-slot sequence word (seqlock style): the writer
//! zeroes it, writes the payload with `Release` stores, then stores the new
//! nonzero sequence with `Release`; a reader that sees the same nonzero
//! sequence before and after its `Acquire` payload loads observed a
//! consistent event, and drops the slot otherwise. The payload accesses
//! themselves carry `Release`/`Acquire` (not `Relaxed`): that is what makes
//! the zeroed sequence word visible to any reader that observes a torn
//! payload value, so the re-check catches it — see the `ordering:` notes in
//! [`TraceRing::record`] and [`TraceRing::events`]. Reads are best-effort by
//! design — tracing must never stall the dispatch path.
//!
//! [`TraceRing::to_chrome_json`] renders the surviving events as a
//! chrome://tracing (about://tracing, Perfetto) loadable JSON document with
//! one track per worker.

use crate::util::json::{Json, JsonObj};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// What happened to a request at one point of the dispatch path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum TraceEventKind {
    /// Admitted into a shard's EDF queue (`arg` = deadline, µs).
    Enqueue = 0,
    /// Shed at admission or dispatch (`arg` = rejection code).
    Shed = 1,
    /// Group lifted from a sibling shard by an idle worker (`arg` = size).
    Steal = 2,
    /// Multiple queued requests coalesced into one dispatch (`arg` = size).
    BatchForm = 3,
    /// Group handed to the execution path (`arg` = size).
    Dispatch = 4,
    /// Request finished and its reply was readied (`arg` = deadline met).
    Retire = 5,
}

impl TraceEventKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceEventKind::Enqueue => "enqueue",
            TraceEventKind::Shed => "shed",
            TraceEventKind::Steal => "steal",
            TraceEventKind::BatchForm => "batch_form",
            TraceEventKind::Dispatch => "dispatch",
            TraceEventKind::Retire => "retire",
        }
    }

    fn from_u64(v: u64) -> Option<TraceEventKind> {
        match v {
            0 => Some(TraceEventKind::Enqueue),
            1 => Some(TraceEventKind::Shed),
            2 => Some(TraceEventKind::Steal),
            3 => Some(TraceEventKind::BatchForm),
            4 => Some(TraceEventKind::Dispatch),
            5 => Some(TraceEventKind::Retire),
            _ => None,
        }
    }
}

/// Rejection code carried in a [`TraceEventKind::Shed`] event's `arg`
/// (mirrors [`crate::serve::queue::Rejection::code`]).
pub fn shed_reason_name(code: u64) -> &'static str {
    match code {
        0 => "below_floor",
        1 => "below_energy_floor",
        2 => "unknown_entry",
        3 => "queue_full",
        4 => "shutting_down",
        _ => "unknown",
    }
}

/// One decoded trace event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Global record ordinal (0-based; ties broken by this in the dump).
    pub seq: u64,
    pub kind: TraceEventKind,
    /// Worker (shard) index; 0 for admission-side events.
    pub worker: u32,
    /// Nanoseconds since the ring was created (monotonic clock).
    pub ts_ns: u64,
    /// Request id from [`crate::telemetry::TelemetryRegistry`]; for group
    /// events, the id of the group head.
    pub req: u64,
    /// Kind-specific payload (see [`TraceEventKind`] docs).
    pub arg: u64,
}

#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    ts_ns: AtomicU64,
    meta: AtomicU64,
    req: AtomicU64,
    arg: AtomicU64,
}

/// The bounded ring. `record` is wait-free; `events` is a best-effort scan.
pub struct TraceRing {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
    epoch: Instant,
}

impl TraceRing {
    /// `capacity` is clamped to at least 16 events.
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(16);
        TraceRing {
            slots: (0..capacity).map(|_| Slot::default()).collect(),
            cursor: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (recorded − capacity ≈ overwritten).
    pub fn recorded(&self) -> u64 {
        // ordering: monotone statistic — no payload is read through this
        // value, so no synchronization is needed.
        self.cursor.load(Ordering::Relaxed)
    }

    pub fn record(&self, kind: TraceEventKind, worker: u32, req: u64, arg: u64) {
        // ordering: the cursor is only a ticket dispenser; slot publication
        // below carries all reader-visible ordering.
        let n = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        let ts = u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX);
        // Invalidate, write payload, publish: see the module docs.
        //
        // ordering: seqlock write side. The zero-store needs no ordering of
        // its own (Relaxed): each payload store below is Release, which
        // keeps the invalidation ordered before the payload value any
        // reader can observe — a reader that Acquire-loads a torn payload
        // value synchronizes with that store, sees seq = 0 (or a later
        // seq) on its re-check, and discards the slot. (The earlier scheme
        // — Release zero-store, Relaxed payload stores — did NOT give this:
        // a Release store only orders *prior* accesses, so the payload
        // stores could become visible before the invalidation and a reader
        // could pass both seq checks around a torn read.) The final
        // nonzero-seq store is Release so a reader whose first seq load
        // acquires it also observes the complete payload.
        slot.seq.store(0, Ordering::Relaxed);
        slot.ts_ns.store(ts, Ordering::Release);
        slot.meta.store(kind as u64 | (u64::from(worker) << 8), Ordering::Release);
        slot.req.store(req, Ordering::Release);
        slot.arg.store(arg, Ordering::Release);
        slot.seq.store(n + 1, Ordering::Release);
    }

    /// Decode every currently-consistent slot, sorted by timestamp.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            // ordering: seqlock read side — see `record`. Acquiring the
            // first seq load pairs with the writer's publishing store: a
            // nonzero value here means the matching payload is visible.
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 {
                continue;
            }
            // ordering: Acquire payload loads pair with the writer's
            // Release payload stores; observing any in-progress value makes
            // that writer's seq = 0 invalidation visible to the re-check
            // below, which then fails s1 == s2. They also pin the re-check:
            // an Acquire load forbids later operations from hoisting above
            // it, so s2 cannot be read before the payload.
            let ts_ns = slot.ts_ns.load(Ordering::Acquire);
            let meta = slot.meta.load(Ordering::Acquire);
            let req = slot.req.load(Ordering::Acquire);
            let arg = slot.arg.load(Ordering::Acquire);
            // ordering: re-check — see the notes on the loads above.
            let s2 = slot.seq.load(Ordering::Acquire);
            if s1 != s2 {
                continue; // torn by a concurrent wrap-around write
            }
            let Some(kind) = TraceEventKind::from_u64(meta & 0xff) else {
                continue;
            };
            out.push(TraceEvent {
                seq: s1 - 1,
                kind,
                worker: (meta >> 8) as u32,
                ts_ns,
                req,
                arg,
            });
        }
        out.sort_by_key(|e| (e.ts_ns, e.seq));
        out
    }

    /// Render as a chrome://tracing JSON document (instant events, one
    /// `tid` track per worker, timestamps in µs).
    pub fn to_chrome_json(&self) -> String {
        let events: Vec<Json> = self
            .events()
            .into_iter()
            .map(|e| {
                let mut args = JsonObj::new();
                args.insert("req", e.req);
                match e.kind {
                    TraceEventKind::Enqueue => args.insert("deadline_us", e.arg),
                    TraceEventKind::Shed => args.insert("reason", shed_reason_name(e.arg)),
                    TraceEventKind::Retire => args.insert("met", e.arg == 1),
                    TraceEventKind::Steal
                    | TraceEventKind::BatchForm
                    | TraceEventKind::Dispatch => args.insert("size", e.arg),
                }
                let mut o = JsonObj::new();
                o.insert("name", e.kind.name());
                o.insert("cat", "medea");
                o.insert("ph", "i");
                o.insert("s", "t");
                o.insert("pid", 1u64);
                o.insert("tid", u64::from(e.worker));
                o.insert("ts", e.ts_ns as f64 / 1e3);
                o.insert("args", args);
                Json::Obj(o)
            })
            .collect();
        let mut root = JsonObj::new();
        root.insert("traceEvents", Json::Arr(events));
        root.insert("displayTimeUnit", "ms");
        Json::Obj(root).to_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_decode_in_order() {
        let ring = TraceRing::new(64);
        ring.record(TraceEventKind::Enqueue, 0, 1, 100_000);
        ring.record(TraceEventKind::Dispatch, 1, 1, 1);
        ring.record(TraceEventKind::Retire, 1, 1, 1);
        let events = ring.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].kind, TraceEventKind::Enqueue);
        assert_eq!(events[2].kind, TraceEventKind::Retire);
        assert_eq!(events[1].worker, 1);
        assert!(events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
        assert_eq!(ring.recorded(), 3);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let ring = TraceRing::new(16);
        for i in 0..40u64 {
            ring.record(TraceEventKind::Retire, 0, i, 1);
        }
        let events = ring.events();
        assert_eq!(events.len(), 16);
        // Only the newest capacity-many survive.
        assert!(events.iter().all(|e| e.req >= 24));
        assert_eq!(ring.recorded(), 40);
    }

    #[test]
    fn chrome_dump_parses_as_json() {
        let ring = TraceRing::new(32);
        ring.record(TraceEventKind::Enqueue, 0, 7, 250_000);
        ring.record(TraceEventKind::Shed, 0, 8, 3);
        ring.record(TraceEventKind::BatchForm, 2, 7, 4);
        let doc = ring.to_chrome_json();
        let v = crate::util::json::parse(&doc).expect("dump parses");
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
        assert_eq!(evs.len(), 3);
        for e in evs {
            assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("i"));
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        }
        // The shed event carries its decoded reason.
        let shed = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("shed"))
            .expect("shed event");
        let reason = shed.get("args").and_then(|a| a.get("reason")).and_then(|r| r.as_str());
        assert_eq!(reason, Some("queue_full"));
    }

    #[test]
    fn concurrent_writers_never_corrupt_readers() {
        use std::sync::Arc;
        // Under Miri the interpreter costs ~3 orders of magnitude; keep the
        // shape (4 writers, concurrent scans, several wrap-arounds of the
        // 128-slot ring) but shrink the volume so the job finishes.
        const WRITES: u64 = if cfg!(miri) { 200 } else { 2_000 };
        const SCANS: usize = if cfg!(miri) { 8 } else { 50 };
        let ring = Arc::new(TraceRing::new(128));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let ring = ring.clone();
                std::thread::spawn(move || {
                    for i in 0..WRITES {
                        ring.record(TraceEventKind::Dispatch, w, i, 1);
                    }
                })
            })
            .collect();
        for _ in 0..SCANS {
            for e in ring.events() {
                assert_eq!(e.kind, TraceEventKind::Dispatch);
                assert!(e.worker < 4 && e.arg == 1);
            }
        }
        for t in writers {
            t.join().expect("writer thread");
        }
        assert_eq!(ring.recorded(), 4 * WRITES);
        assert_eq!(ring.events().len(), 128);
    }
}
