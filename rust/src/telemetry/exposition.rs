//! Prometheus text exposition (format 0.0.4) over a minimal multi-endpoint
//! blocking HTTP responder, plus the matching bounded GET client.
//!
//! [`render_prometheus`] turns a [`RegistrySnapshot`] into the text format;
//! [`MetricsServer`] binds a `std::net::TcpListener` (one short-lived
//! thread, no framework, no dependency) and routes:
//!
//! * `GET /metrics` — a fresh exposition, with the SLO gauges appended when
//!   a [`SloEngine`] is attached;
//! * `GET /healthz` — liveness (the responder thread is up);
//! * `GET /readyz` — readiness through the pool's [`ReadinessProbe`]
//!   (accepting, admission queues below the saturation watermark), `503`
//!   when the pool is stopping or saturated;
//! * `GET /slo` — the latest SLO evaluation as JSON.
//!
//! Unknown paths get `404`, non-GET methods `405` — a scraper typo no
//! longer silently receives a well-formed exposition. [`scrape`] /
//! [`scrape_with`] ([`http_get`] underneath) are the tiny clients behind
//! `medea scrape` and `medea health`, with explicit connect/read deadlines
//! and bounded retries so CI needs no shell retry loops.
//!
//! Histograms are downsampled from the 640 fine log-linear buckets to 15
//! power-of-4 `le` bounds plus `+Inf` — coarse enough to keep a scrape small,
//! fine enough for rate/percentile queries. Time series are exported in
//! seconds, energy in microjoules, batch sizes over linear bounds.

use crate::telemetry::hist::{bucket_upper, HistData};
use crate::telemetry::ledger::{LedgerEntrySnapshot, LedgerSnapshot};
use crate::telemetry::registry::{RegistrySnapshot, WorkerSnapshot};
use crate::telemetry::slo::SloEngine;
use crate::telemetry::TelemetryRegistry;
use crate::util::error::{anyhow, bail, Result};
use std::fmt::Write as _;
use std::io::{Read as _, Write as _};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Histogram `le` bounds for nanosecond-valued series: 1 µs · 4^k.
const TIME_BOUNDS_NS: [u64; 15] = [
    1_000,
    4_000,
    16_000,
    64_000,
    256_000,
    1_024_000,
    4_096_000,
    16_384_000,
    65_536_000,
    262_144_000,
    1_048_576_000,
    4_194_304_000,
    16_777_216_000,
    67_108_864_000,
    268_435_456_000,
];

/// Histogram `le` bounds for nanojoule-valued series: 1 µJ · 4^k.
const ENERGY_BOUNDS_NJ: [u64; 15] = TIME_BOUNDS_NS;

/// Render one snapshot in Prometheus text exposition format 0.0.4.
pub fn render_prometheus(snap: &RegistrySnapshot) -> String {
    let mut out = String::with_capacity(16 * 1024);
    let base = format!(
        "platform=\"{}\",workload=\"{}\"",
        escape_label(&snap.platform),
        escape_label(&snap.workload)
    );
    let workers: Vec<(String, &WorkerSnapshot)> = snap
        .workers
        .iter()
        .enumerate()
        .map(|(i, w)| (format!("{base},worker=\"{i}\""), w))
        .collect();

    family(&mut out, "medea_uptime_seconds", "gauge", "Seconds since the pool registry started.");
    series(&mut out, "medea_uptime_seconds", &base, snap.uptime.as_secs_f64());

    counter(&mut out, "medea_requests_total", "Requests served.", &workers, |w| w.requests);
    counter(
        &mut out,
        "medea_seizures_detected_total",
        "Served windows whose prediction flagged a seizure.",
        &workers,
        |w| w.seizures,
    );
    counter(
        &mut out,
        "medea_deadline_misses_total",
        "Served requests whose simulated schedule missed its deadline.",
        &workers,
        |w| w.deadline_misses,
    );
    counter(
        &mut out,
        "medea_steals_total",
        "Dispatch groups lifted from a sibling shard by an idle worker.",
        &workers,
        |w| w.steals,
    );
    counter(
        &mut out,
        "medea_stolen_requests_total",
        "Requests served through stolen dispatches.",
        &workers,
        |w| w.stolen_requests,
    );
    counter(
        &mut out,
        "medea_spurious_wakeups_total",
        "Worker parks that ended without a wake token (heartbeat expiry).",
        &workers,
        |w| w.spurious_wakeups,
    );

    family(
        &mut out,
        "medea_batch_window_seconds",
        "gauge",
        "Effective batch fill window chosen for the latest dispatch.",
    );
    for (labels, w) in &workers {
        series(&mut out, "medea_batch_window_seconds", labels, w.batch_window_ns as f64 / 1e9);
    }

    family(
        &mut out,
        "medea_sim_energy_joules_total",
        "counter",
        "Simulated on-device energy across served windows.",
    );
    for (labels, w) in &workers {
        series(&mut out, "medea_sim_energy_joules_total", labels, w.sim_energy_nj as f64 / 1e9);
    }
    family(
        &mut out,
        "medea_sim_active_seconds_total",
        "counter",
        "Simulated on-device active time across served windows.",
    );
    for (labels, w) in &workers {
        series(&mut out, "medea_sim_active_seconds_total", labels, w.sim_active_ns as f64 / 1e9);
    }

    family(
        &mut out,
        "medea_shed_requests_total",
        "counter",
        "Requests shed at admission, by typed rejection reason.",
    );
    for (reason, n) in [
        ("below_floor", snap.shed_below_floor),
        ("queue_full", snap.shed_queue_full),
        ("unknown_entry", snap.shed_unknown_entry),
        ("shutting_down", snap.shed_shutting_down),
    ] {
        series(
            &mut out,
            "medea_shed_requests_total",
            &format!("{base},shed_reason=\"{reason}\""),
            n as f64,
        );
    }

    family(
        &mut out,
        "medea_batch_size",
        "histogram",
        "Coalesced requests per dispatch (1 = solo).",
    );
    for (labels, w) in &workers {
        batch_histogram(&mut out, labels, &w.batch_hist);
    }

    for (name, help, pick) in [
        (
            "medea_host_latency_seconds",
            "End-to-end host latency, submit to reply.",
            (|w: &WorkerSnapshot| &w.host) as fn(&WorkerSnapshot) -> &HistData,
        ),
        (
            "medea_queue_wait_seconds",
            "Time queued before a worker dequeued the request.",
            |w: &WorkerSnapshot| &w.queue_wait,
        ),
        (
            "medea_head_laxity_seconds",
            "Dispatch-group head's remaining slack at dequeue.",
            |w: &WorkerSnapshot| &w.laxity,
        ),
        (
            "medea_dispatch_seconds",
            "Execution time of one dispatch, dequeue to retire.",
            |w: &WorkerSnapshot| &w.dispatch,
        ),
        (
            "medea_wakeup_latency_seconds",
            "Steal-wake delivery: victim posts the wake to thief waking.",
            |w: &WorkerSnapshot| &w.wake,
        ),
    ] {
        family(&mut out, name, "histogram", help);
        for (labels, w) in &workers {
            scaled_histogram(&mut out, name, labels, pick(w), &TIME_BOUNDS_NS, 1e9);
        }
    }

    family(
        &mut out,
        "medea_request_energy_microjoules",
        "histogram",
        "Simulated energy per served request.",
    );
    for (labels, w) in &workers {
        scaled_histogram(
            &mut out,
            "medea_request_energy_microjoules",
            labels,
            &w.energy,
            &ENERGY_BOUNDS_NJ,
            1e3,
        );
    }

    family(
        &mut out,
        "medea_queue_depth",
        "gauge",
        "Admission queue depth of the worker's shard when snapped.",
    );
    for (labels, w) in &workers {
        series(&mut out, "medea_queue_depth", labels, w.queue_depth as f64);
    }

    if let Some(ledger) = &snap.ledger {
        render_ledger(&mut out, &base, ledger);
    }

    out
}

/// Emit the energy attribution ledger families (see
/// [`crate::telemetry::ledger`]). The label sets are fixed at pool start —
/// the tables are sized from the atlas — so the series count is bounded;
/// zero cells are emitted too, which keeps the counters `rate()`-able and
/// the exposition layout stable.
fn render_ledger(out: &mut String, base: &str, ledger: &LedgerSnapshot) {
    for (name, help, pick) in [
        (
            "medea_pe_energy_joules_total",
            "Attributed simulated energy per (entry, PE, V-F point).",
            (|e: &LedgerEntrySnapshot, cell: usize| e.pe_energy_nj[cell] as f64 / 1e9)
                as fn(&LedgerEntrySnapshot, usize) -> f64,
        ),
        (
            "medea_pe_busy_seconds_total",
            "Attributed simulated busy time per (entry, PE, V-F point).",
            |e: &LedgerEntrySnapshot, cell: usize| e.pe_busy_ns[cell] as f64 / 1e9,
        ),
    ] {
        family(out, name, "counter", help);
        for e in &ledger.entries {
            let vfs = e.vf_labels.len();
            for (p, pe) in e.pe_labels.iter().enumerate() {
                for (v, vf) in e.vf_labels.iter().enumerate() {
                    let labels = format!(
                        "{base},entry=\"{}\",pe=\"{}\",vf=\"{}\"",
                        escape_label(&e.label),
                        escape_label(pe),
                        escape_label(vf)
                    );
                    series(out, name, &labels, pick(e, p * vfs + v));
                }
            }
        }
    }
    for (name, kind, help, pick) in [
        (
            "medea_knot_dispatches_total",
            "counter",
            "Dispatches resolved against this atlas knot.",
            (|e: &LedgerEntrySnapshot, k: usize| e.knot_dispatches[k] as f64)
                as fn(&LedgerEntrySnapshot, usize) -> f64,
        ),
        (
            "medea_atlas_drift_ratio",
            "gauge",
            "EWMA of realized vs. modeled dispatch time per knot (worst worker; 0 = no samples).",
            |e: &LedgerEntrySnapshot, k: usize| e.knot_drift[k],
        ),
    ] {
        family(out, name, kind, help);
        for e in &ledger.entries {
            for (k, knot) in e.knot_labels.iter().enumerate() {
                let labels = format!(
                    "{base},entry=\"{}\",knot=\"{}\"",
                    escape_label(&e.label),
                    escape_label(knot)
                );
                series(out, name, &labels, pick(e, k));
            }
        }
    }
    family(
        out,
        "medea_unattributed_dispatches_total",
        "counter",
        "Dispatches whose entry or knot was absent from the ledger tables.",
    );
    series(out, "medea_unattributed_dispatches_total", base, ledger.unattributed as f64);
}

fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn series(out: &mut String, name: &str, labels: &str, value: f64) {
    let _ = writeln!(out, "{name}{{{labels}}} {value}");
}

fn counter(
    out: &mut String,
    name: &str,
    help: &str,
    workers: &[(String, &WorkerSnapshot)],
    pick: impl Fn(&WorkerSnapshot) -> u64,
) {
    family(out, name, "counter", help);
    for (labels, w) in workers {
        series(out, name, labels, pick(w) as f64);
    }
}

/// Emit one histogram family member from fine log-linear buckets, mapped
/// onto `bounds` (raw units) and reported divided by `scale`.
fn scaled_histogram(
    out: &mut String,
    name: &str,
    labels: &str,
    h: &HistData,
    bounds: &[u64],
    scale: f64,
) {
    let mut cum = vec![0u64; bounds.len()];
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        if let Some(j) = bounds.iter().position(|&b| bucket_upper(i) <= b) {
            cum[j] += c;
        }
    }
    let mut running = 0u64;
    for (j, &b) in bounds.iter().enumerate() {
        running += cum[j];
        let le = b as f64 / scale;
        let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le}\"}} {running}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum() as f64 / scale);
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count());
}

/// Emit the linear batch-size histogram (`le` = 1, 2, 4, ... 64, +Inf).
fn batch_histogram(out: &mut String, labels: &str, hist: &[u64]) {
    let name = "medea_batch_size";
    let total: u64 = hist.iter().sum();
    let weighted: u64 = hist.iter().enumerate().map(|(i, &n)| (i as u64 + 1) * n).sum();
    for le in [1usize, 2, 4, 8, 16, 32, 64] {
        let running: u64 = hist.iter().take(le).sum();
        let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{le}\"}} {running}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {total}");
    let _ = writeln!(out, "{name}_sum{{{labels}}} {weighted}");
    let _ = writeln!(out, "{name}_count{{{labels}}} {total}");
}

pub(crate) fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// A `/readyz` verdict: whether the pool is accepting work, plus a
/// human-readable reason (queue depth vs capacity, stopping, …).
#[derive(Debug, Clone)]
pub struct Readiness {
    pub ready: bool,
    pub detail: String,
}

impl Readiness {
    pub fn ready(detail: impl Into<String>) -> Readiness {
        Readiness { ready: true, detail: detail.into() }
    }

    pub fn unready(detail: impl Into<String>) -> Readiness {
        Readiness { ready: false, detail: detail.into() }
    }
}

/// How a pool reports readiness to the `/readyz` endpoint (see
/// `ServePool::readiness_probe` / `FleetPool::readiness_probe`).
pub type ReadinessProbe = Arc<dyn Fn() -> Readiness + Send + Sync>;

/// What the responder thread serves: the registry plus optional SLO and
/// readiness surfaces.
struct Routes {
    registry: Arc<TelemetryRegistry>,
    slo: Option<Arc<SloEngine>>,
    ready: Option<ReadinessProbe>,
}

const CT_TEXT: &str = "text/plain; charset=utf-8";
const CT_PROM: &str = "text/plain; version=0.0.4; charset=utf-8";
const CT_JSON: &str = "application/json";

/// A blocking single-threaded observability endpoint over `std::net`.
///
/// Routes `/metrics`, `/healthz`, `/readyz`, and `/slo` (see the module
/// docs); every response reads fresh state, nothing is cached. Dropping the
/// server stops the thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`; port 0 picks a free port) and
    /// start answering scrapes. Metrics-only: no SLO engine, no readiness
    /// probe ( `/readyz` then only proves the responder is up).
    pub fn start(addr: &str, registry: Arc<TelemetryRegistry>) -> Result<MetricsServer> {
        Self::start_with(addr, registry, None, None)
    }

    /// [`MetricsServer::start`] with the full health surface: an SLO engine
    /// behind `/slo` (and its gauges on `/metrics`) and a pool readiness
    /// probe behind `/readyz`.
    pub fn start_with(
        addr: &str,
        registry: Arc<TelemetryRegistry>,
        slo: Option<Arc<SloEngine>>,
        ready: Option<ReadinessProbe>,
    ) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow!("metrics-addr `{addr}`: {e}"))?;
        let local = listener.local_addr().map_err(|e| anyhow!("metrics-addr `{addr}`: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let routes = Routes { registry, slo, ready };
        let handle = std::thread::Builder::new()
            .name("medea-metrics".into())
            .spawn({
                let stop = stop.clone();
                move || serve_loop(&listener, &routes, &stop)
            })
            .map_err(|e| anyhow!("spawning metrics server: {e}"))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        // ordering: plain shutdown flag with no payload protocol — the
        // accept loop only polls it, and the wake-up connection below is
        // what actually delivers the signal promptly.
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop by connecting to it once ourselves.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(IpAddr::V4(Ipv4Addr::LOCALHOST));
        }
        if TcpStream::connect_timeout(&wake, Duration::from_millis(500)).is_ok() {
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn serve_loop(listener: &TcpListener, routes: &Routes, stop: &AtomicBool) {
    for conn in listener.incoming() {
        // ordering: relaxed shutdown poll, see `MetricsServer::drop`.
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let mut stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
        // Drain the request head, then route on the request line.
        let mut head = Vec::new();
        let mut buf = [0u8; 1024];
        loop {
            match stream.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    head.extend_from_slice(&buf[..n]);
                    if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        let head = String::from_utf8_lossy(&head);
        let (status, content_type, body) = route(routes, &head);
        let response = format!(
            "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        );
        let _ = stream.write_all(response.as_bytes());
    }
}

/// Dispatch one request head to a response: `(status line, content type,
/// body)`. Only GET is served; unknown paths are a `404`, not a silent
/// exposition.
fn route(routes: &Routes, head: &str) -> (&'static str, &'static str, String) {
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let (Some(method), Some(target)) = (parts.next(), parts.next()) else {
        return ("400 Bad Request", CT_TEXT, "malformed request line\n".into());
    };
    if method != "GET" {
        let body = format!("method {method} not allowed; use GET\n");
        return ("405 Method Not Allowed", CT_TEXT, body);
    }
    let path = target.split('?').next().unwrap_or(target);
    match path {
        "/metrics" => {
            let mut body = render_prometheus(&routes.registry.snapshot());
            if let Some(engine) = &routes.slo {
                body.push_str(&engine.render_gauges());
            }
            ("200 OK", CT_PROM, body)
        }
        "/healthz" => ("200 OK", CT_TEXT, "ok\n".into()),
        "/readyz" => match &routes.ready {
            Some(probe) => {
                let r = probe();
                if r.ready {
                    ("200 OK", CT_TEXT, format!("ready: {}\n", r.detail))
                } else {
                    ("503 Service Unavailable", CT_TEXT, format!("unready: {}\n", r.detail))
                }
            }
            // No probe attached: the responder being up is all the
            // readiness there is.
            None => ("200 OK", CT_TEXT, "ready\n".into()),
        },
        "/slo" => match &routes.slo {
            Some(engine) => ("200 OK", CT_JSON, engine.status_json().to_pretty()),
            None => ("404 Not Found", CT_TEXT, "no SLO engine configured\n".into()),
        },
        other => ("404 Not Found", CT_TEXT, format!("no route for {other}\n")),
    }
}

/// One bounded HTTP GET against a [`MetricsServer`]-style responder:
/// connect, write, and read each run under `timeout`. Returns the status
/// code and body (including non-2xx bodies — callers decide what a failure
/// is).
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<(u16, String)> {
    use std::net::ToSocketAddrs as _;
    let timeout = timeout.max(Duration::from_millis(1));
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| anyhow!("resolve `{addr}`: {e}"))?
        .next()
        .ok_or_else(|| anyhow!("resolve `{addr}`: no address"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)
        .map_err(|e| anyhow!("connect `{addr}`: {e}"))?;
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    stream
        .write_all(format!("GET {path} HTTP/1.0\r\nHost: {addr}\r\n\r\n").as_bytes())
        .map_err(|e| anyhow!("request `{addr}{path}`: {e}"))?;
    let mut raw = String::new();
    stream
        .read_to_string(&mut raw)
        .map_err(|e| anyhow!("read `{addr}{path}`: {e}"))?;
    let Some((head, body)) = raw.split_once("\r\n\r\n") else {
        bail!("`{addr}{path}`: malformed HTTP response");
    };
    let status = head.lines().next().unwrap_or_default();
    let code = status
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| anyhow!("`{addr}{path}`: bad status line `{status}`"))?;
    Ok((code, body.to_string()))
}

/// Fetch one exposition from a running [`MetricsServer`]; returns the body.
pub fn scrape(addr: &str) -> Result<String> {
    scrape_with(addr, Duration::from_secs(5), 0)
}

/// [`scrape`] with explicit connect/read deadlines and bounded retries
/// (exponential backoff from 50 ms, capped at 1 s) — what `medea scrape
/// --timeout-ms --retries` runs, so CI needs no shell retry loop.
pub fn scrape_with(addr: &str, timeout: Duration, retries: u32) -> Result<String> {
    let mut backoff = Duration::from_millis(50);
    let mut attempt = 0;
    loop {
        let err = match http_get(addr, "/metrics", timeout) {
            Ok((200, body)) => return Ok(body),
            Ok((code, _)) => anyhow!("scrape `{addr}`: HTTP {code}"),
            Err(e) => e,
        };
        if attempt >= retries {
            return Err(err);
        }
        attempt += 1;
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(Duration::from_secs(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_registry() -> Arc<TelemetryRegistry> {
        let reg = Arc::new(TelemetryRegistry::new("heeptimize", "tsd-core", 2));
        let w0 = reg.worker(0);
        w0.record(false, true, 120e-6, 0.01, Duration::from_millis(2));
        w0.record(true, false, 90e-6, 0.02, Duration::from_millis(5));
        w0.record_batch(2);
        w0.record_queue_wait(Duration::from_micros(40));
        w0.record_head_laxity(Duration::from_millis(80));
        w0.record_dispatch_time(Duration::from_millis(4));
        reg.worker(1).record(false, true, 50e-6, 0.01, Duration::from_micros(700));
        reg.record_shed(&crate::serve::queue::Rejection::QueueFull { capacity: 8 });
        reg
    }

    #[test]
    fn exposition_is_well_formed() {
        let body = render_prometheus(&sample_registry().snapshot());
        assert!(body.contains("# TYPE medea_requests_total counter"));
        assert!(body.contains("# TYPE medea_host_latency_seconds histogram"));
        assert!(body.contains(
            "medea_requests_total{platform=\"heeptimize\",workload=\"tsd-core\",worker=\"0\"} 2"
        ));
        assert!(body.contains("shed_reason=\"queue_full\"} 1"));
        assert!(body.contains("medea_batch_size_bucket{"));
        assert!(body.contains("# TYPE medea_wakeup_latency_seconds histogram"));
        assert!(body.contains("# TYPE medea_spurious_wakeups_total counter"));
        assert!(body.contains("# TYPE medea_batch_window_seconds gauge"));
        // Every non-comment line is `name{labels} value` with a float value.
        for line in body.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert!(line.starts_with("medea_"), "bad metric line: {line}");
            let (_, value) = line.rsplit_once(' ').expect("value separator");
            assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
        }
        // Histogram invariants: count series match the +Inf bucket.
        let inf = body
            .lines()
            .filter(|l| l.contains("medea_host_latency_seconds_bucket") && l.contains("+Inf"))
            .count();
        assert_eq!(inf, 2, "one +Inf bucket per worker");
    }

    #[test]
    fn ledger_families_render_byte_stable() {
        use crate::manager::schedule::Decision;
        use crate::platform::PeId;
        use crate::telemetry::ledger::{ledger_from_prometheus, EnergyLedger, LedgerEntrySpec};
        use crate::tiling::modes::TilingMode;
        use crate::util::units::{Energy, Time};
        let reg = TelemetryRegistry::new("heeptimize", "tsd-core", 1);
        reg.worker(0).set_queue_depth(3);
        let ledger = EnergyLedger::new(1, &[LedgerEntrySpec {
            platform: "heeptimize".into(),
            workload: "tsd-core".into(),
            pe_labels: vec!["cpu".into()],
            vf_labels: vec!["0.80V@170MHz".into(), "0.90V@250MHz".into()],
            knot_deadlines: vec![Time::from_ms(50.0)],
        }]);
        let decisions = [Decision {
            kernel: 0,
            pe: PeId(0),
            vf_idx: 1,
            mode: TilingMode::SingleBuffer,
            time: Time::from_us(100.0),
            energy: Energy::from_uj(2.0),
        }];
        // Powers of two throughout so the drift ratio is exactly 2.0.
        ledger.record_dispatch(
            0,
            0,
            Time::from_ms(50.0),
            &decisions,
            1,
            Duration::from_micros(15_625),
            Time(0.0078125),
        );
        reg.install_ledger(ledger);
        let body = render_prometheus(&reg.snapshot());
        let start = body.find("# HELP medea_queue_depth").expect("queue depth family");
        let labels = "platform=\"heeptimize\",workload=\"tsd-core\"";
        let entry = "entry=\"heeptimize/tsd-core\"";
        let expected = format!(
            "# HELP medea_queue_depth Admission queue depth of the worker's shard when snapped.\n\
             # TYPE medea_queue_depth gauge\n\
             medea_queue_depth{{{labels},worker=\"0\"}} 3\n\
             # HELP medea_pe_energy_joules_total Attributed simulated energy per (entry, PE, V-F point).\n\
             # TYPE medea_pe_energy_joules_total counter\n\
             medea_pe_energy_joules_total{{{labels},{entry},pe=\"cpu\",vf=\"0.80V@170MHz\"}} 0\n\
             medea_pe_energy_joules_total{{{labels},{entry},pe=\"cpu\",vf=\"0.90V@250MHz\"}} 0.000002\n\
             # HELP medea_pe_busy_seconds_total Attributed simulated busy time per (entry, PE, V-F point).\n\
             # TYPE medea_pe_busy_seconds_total counter\n\
             medea_pe_busy_seconds_total{{{labels},{entry},pe=\"cpu\",vf=\"0.80V@170MHz\"}} 0\n\
             medea_pe_busy_seconds_total{{{labels},{entry},pe=\"cpu\",vf=\"0.90V@250MHz\"}} 0.0001\n\
             # HELP medea_knot_dispatches_total Dispatches resolved against this atlas knot.\n\
             # TYPE medea_knot_dispatches_total counter\n\
             medea_knot_dispatches_total{{{labels},{entry},knot=\"50.000ms\"}} 1\n\
             # HELP medea_atlas_drift_ratio EWMA of realized vs. modeled dispatch time per knot (worst worker; 0 = no samples).\n\
             # TYPE medea_atlas_drift_ratio gauge\n\
             medea_atlas_drift_ratio{{{labels},{entry},knot=\"50.000ms\"}} 2\n\
             # HELP medea_unattributed_dispatches_total Dispatches whose entry or knot was absent from the ledger tables.\n\
             # TYPE medea_unattributed_dispatches_total counter\n\
             medea_unattributed_dispatches_total{{{labels}}} 0\n"
        );
        assert_eq!(&body[start..], expected, "ledger family golden drifted");
        // And the scrape re-ingests into the same snapshot the pool holds.
        let parsed = ledger_from_prometheus(&body).expect("re-ingest");
        let held = reg.snapshot().ledger.expect("ledger snapshot");
        assert_eq!(parsed, held);
    }

    #[test]
    fn server_answers_a_live_scrape() {
        let reg = sample_registry();
        let server = MetricsServer::start("127.0.0.1:0", reg.clone()).expect("bind");
        let addr = server.addr().to_string();
        let body = scrape(&addr).expect("scrape");
        assert!(body.contains("medea_requests_total{"));
        // New samples show up on the next scrape: it is live, not cached.
        reg.worker(1).record(false, true, 10e-6, 0.001, Duration::from_micros(300));
        let body2 = scrape(&addr).expect("second scrape");
        assert!(body2.contains(
            "medea_requests_total{platform=\"heeptimize\",workload=\"tsd-core\",worker=\"1\"} 2"
        ));
        // Dropping the server stops the accept loop; scrapes then fail.
        drop(server);
        assert!(scrape(&addr).is_err(), "server still answering after drop");
    }

    #[test]
    fn scrape_rejects_nothing_listening() {
        assert!(scrape("127.0.0.1:1").is_err());
    }

    #[test]
    fn routes_reject_unknown_paths_and_methods() {
        let reg = sample_registry();
        let server = MetricsServer::start("127.0.0.1:0", reg).expect("bind");
        let addr = server.addr().to_string();
        // Unknown path: 404, not a silent exposition.
        let (code, body) = http_get(&addr, "/nope", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 404, "body: {body}");
        assert!(!body.contains("medea_requests_total"), "404 must not carry the exposition");
        // Non-GET method: 405.
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream
            .write_all(format!("POST /metrics HTTP/1.0\r\nHost: {addr}\r\n\r\n").as_bytes())
            .expect("write");
        let mut raw = String::new();
        stream.read_to_string(&mut raw).expect("read");
        assert!(raw.starts_with("HTTP/1.0 405"), "{raw}");
        // Liveness; readiness with no probe attached means "server is up".
        let (code, body) = http_get(&addr, "/healthz", Duration::from_secs(2)).expect("http");
        assert_eq!((code, body.as_str()), (200, "ok\n"));
        let (code, _) = http_get(&addr, "/readyz", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 200);
        // /slo without an engine: 404.
        let (code, _) = http_get(&addr, "/slo", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 404);
        // /metrics is still the exposition, query strings ignored.
        let (code, body) = http_get(&addr, "/metrics?x=1", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 200);
        assert!(body.contains("# TYPE medea_requests_total counter"));
    }

    #[test]
    fn readiness_probe_and_slo_endpoints_answer() {
        use crate::telemetry::slo::{SloEngine, SloSpec};
        let reg = sample_registry();
        let engine = SloEngine::new(SloSpec::default(), reg.clone(), None, None);
        let saturated = Arc::new(AtomicBool::new(false));
        let probe: ReadinessProbe = {
            let saturated = saturated.clone();
            Arc::new(move || {
                // ordering: independent test flag, no publication needed.
                if saturated.load(Ordering::Relaxed) {
                    Readiness::unready("queue 256/256")
                } else {
                    Readiness::ready("queue 0/256")
                }
            })
        };
        let server = MetricsServer::start_with("127.0.0.1:0", reg, Some(engine), Some(probe))
            .expect("bind");
        let addr = server.addr().to_string();
        let (code, body) = http_get(&addr, "/readyz", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 200);
        assert!(body.contains("queue 0/256"), "{body}");
        // ordering: independent test flag, see the probe closure above.
        saturated.store(true, Ordering::Relaxed);
        let (code, body) = http_get(&addr, "/readyz", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 503);
        assert!(body.contains("queue 256/256"), "{body}");
        let (code, body) = http_get(&addr, "/slo", Duration::from_secs(2)).expect("http");
        assert_eq!(code, 200);
        let doc = crate::util::json::parse(&body).expect("slo json");
        assert_eq!(doc.get("state").and_then(|v| v.as_str()), Some("ok"));
        // The SLO gauges ride the exposition when an engine is attached.
        let metrics = scrape(&addr).expect("scrape");
        assert!(metrics.contains("# TYPE medea_slo_state gauge"), "{metrics}");
    }

    #[test]
    fn scrape_with_retries_back_off_then_error() {
        let t0 = std::time::Instant::now();
        assert!(scrape_with("127.0.0.1:1", Duration::from_millis(100), 2).is_err());
        // Two retries sleep 50 ms + 100 ms between attempts.
        assert!(t0.elapsed() >= Duration::from_millis(100), "retries must back off");
    }

    #[test]
    fn labels_escape_cleanly() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
