//! DMA transfer model: L2 ↔ PE local memory.

use crate::platform::pe::DmaSpec;
use crate::util::units::{Bytes, Cycles};

/// Cycles to move `bytes` across one DMA path: fixed programming cost plus
/// bandwidth-limited streaming.
pub fn dma_cycles(spec: DmaSpec, bytes: Bytes) -> Cycles {
    if bytes == Bytes::ZERO {
        return Cycles::ZERO;
    }
    let stream = (bytes.raw() as f64 / spec.bytes_per_cycle).ceil() as u64;
    Cycles(spec.setup_cycles + stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: DmaSpec = DmaSpec {
        bytes_per_cycle: 4.0,
        setup_cycles: 96,
    };

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(dma_cycles(SPEC, Bytes::ZERO), Cycles::ZERO);
    }

    #[test]
    fn bandwidth_and_setup() {
        assert_eq!(dma_cycles(SPEC, Bytes(4000)), Cycles(96 + 1000));
        // Partial beat rounds up.
        assert_eq!(dma_cycles(SPEC, Bytes(5)), Cycles(96 + 2));
    }

    #[test]
    fn wide_port_is_faster() {
        let wide = DmaSpec {
            bytes_per_cycle: 16.0,
            setup_cycles: 72,
        };
        assert!(dma_cycles(wide, Bytes(64 * 1024)) < dma_cycles(SPEC, Bytes(64 * 1024)));
    }
}
