//! Per-PE analytical cycle models — the FPGA prototype stand-in.
//!
//! Produces *processing-only* cycle counts (data movement is modeled by
//! [`super::dma`] and composed by [`crate::tiling`]). Constants are
//! microarchitecturally motivated and calibrated to the paper's anchors:
//!
//! * CPU (CV32E40P, RV32IMC): no SIMD, ~2 cycles/int-MAC with load/store
//!   amortization; soft-float multiplies cost tens of cycles. The paper's
//!   Table 4 "modified" kernels (Taylor softmax, PWL GeLU, no-log FFT) get
//!   integer-friendly costs; the "original" float kernels get soft-float
//!   costs (used only by the Table 4 reproduction).
//! * CGRA (OpenEdgeCGRA, 4×4 RCs): ~4 int MACs/cycle once configured;
//!   a per-launch configuration-load overhead and a per-tile restart cost.
//! * NMC (Carus): vector unit over the VRF; throughput scales inversely
//!   with element width (more lanes at int8); kernel code is loaded into
//!   its eMEM once per launch.

use crate::ir::{DataWidth, Kernel, KernelType, Shape};
use crate::platform::pe::PeClass;
use crate::util::units::Cycles;

/// Processing-cycle model for every (PE class, kernel type, width) combo.
#[derive(Debug, Clone)]
pub struct CycleModel {
    /// CPU cycles per "op" (see [`Shape::ops`]) for integer widths.
    pub cpu_int: TypeCosts,
    /// CPU cycles per op for float32 (soft-float on RV32IMC).
    pub cpu_f32: TypeCosts,
    /// CGRA cycles per op (integer only).
    pub cgra: TypeCosts,
    /// Carus cycles per op at int8; int16 ×2, int32 ×4 (lane splitting).
    pub nmc_int8: TypeCosts,
    /// Per-launch fixed overhead (configuration / kernel-code load).
    pub launch_overhead: PerClass<u64>,
    /// Per-tile restart overhead (pointer setup, interrupt round-trip).
    pub tile_overhead: PerClass<u64>,
}

/// Cycles-per-op table indexed by kernel type.
#[derive(Debug, Clone, Copy)]
pub struct TypeCosts {
    pub matmul: f64,
    pub conv2d: f64,
    pub add: f64,
    pub norm: f64,
    pub softmax: f64,
    pub gelu: f64,
    pub transpose: f64,
    pub scale: f64,
    pub class_concat: f64,
    pub fft_mag: f64,
}

impl TypeCosts {
    pub fn get(&self, ty: KernelType) -> f64 {
        match ty {
            KernelType::MatMul => self.matmul,
            KernelType::Conv2d => self.conv2d,
            KernelType::Add => self.add,
            KernelType::Norm => self.norm,
            KernelType::Softmax => self.softmax,
            KernelType::Gelu => self.gelu,
            KernelType::Transpose => self.transpose,
            KernelType::Scale => self.scale,
            KernelType::ClassConcat => self.class_concat,
            KernelType::FftMag => self.fft_mag,
        }
    }
}

/// A value per PE class.
#[derive(Debug, Clone, Copy)]
pub struct PerClass<T> {
    pub cpu: T,
    pub cgra: T,
    pub nmc: T,
}

impl<T: Copy> PerClass<T> {
    pub fn get(&self, class: PeClass) -> T {
        match class {
            PeClass::RiscvCpu => self.cpu,
            PeClass::Cgra => self.cgra,
            PeClass::Nmc => self.nmc,
        }
    }
}

/// Marker for "not executable by this model" (e.g. float on an accelerator).
pub const UNSUPPORTED: f64 = f64::INFINITY;

impl CycleModel {
    /// The calibrated HEEPtimize model.
    pub fn heeptimize() -> CycleModel {
        CycleModel {
            cpu_int: TypeCosts {
                matmul: 1.8,
                conv2d: 1.9,
                add: 2.6,
                norm: 2.6,        // ops() already counts 3 passes/element
                softmax: 19.0,    // 3-coefficient Taylor ConSmax (modified)
                gelu: 6.0,        // piece-wise-linear (modified)
                transpose: 2.2,
                scale: 2.4,
                class_concat: 1.5,
                fft_mag: 165.0,   // magnitude-only FFT, fixed-point twiddles
            },
            cpu_f32: TypeCosts {
                matmul: 14.0,
                conv2d: 14.0,
                add: 9.0,
                norm: 11.0,
                softmax: 1430.0, // soft-float exp()/div per element (original)
                gelu: 85.0,      // soft-float tanh-based GeLU (original)
                transpose: 2.2,
                scale: 9.0,
                class_concat: 1.5,
                fft_mag: 165.0,  // float butterflies via FPU-less mul: ~same as above
            },
            cgra: TypeCosts {
                matmul: 0.28,
                conv2d: 0.31,
                add: 0.22,
                norm: 0.26,
                softmax: UNSUPPORTED,
                gelu: UNSUPPORTED,
                transpose: 0.32,
                scale: 0.22,
                class_concat: UNSUPPORTED,
                fft_mag: UNSUPPORTED,
            },
            nmc_int8: TypeCosts {
                matmul: 0.24,
                conv2d: 0.29,
                add: 0.12,
                norm: 0.16,
                softmax: UNSUPPORTED,
                gelu: UNSUPPORTED,
                transpose: 0.29, // strided VRF access, bank conflicts
                scale: 0.12,
                class_concat: UNSUPPORTED,
                fft_mag: UNSUPPORTED,
            },
            launch_overhead: PerClass {
                cpu: 60,
                cgra: 1150, // context/bitstream load into RC program memories
                nmc: 820,   // kernel code load into eMEM by the host
            },
            // Per-tile cost is host-driven on these platforms: an interrupt
            // round-trip plus DMA channel reprogramming by the CV32E40P.
            tile_overhead: PerClass {
                cpu: 0,
                cgra: 420,
                nmc: 360,
            },
        }
    }

    /// Width multiplier for the NMC (lanes split by element width).
    fn nmc_width_factor(dw: DataWidth) -> f64 {
        match dw {
            DataWidth::Int8 => 1.0,
            DataWidth::Int16 => 1.9,
            DataWidth::Int32 => 3.6,
            DataWidth::Float32 => UNSUPPORTED,
        }
    }

    /// Processing-only cycles for `ops` operations of kernel type `ty` at
    /// width `dw` on PE class `class`. `None` when the combination is not
    /// executable (the caller should already have filtered via `Λ_op`).
    pub fn cycles_for_ops(
        &self,
        class: PeClass,
        ty: KernelType,
        dw: DataWidth,
        ops: u64,
    ) -> Option<Cycles> {
        let cpo = match class {
            PeClass::RiscvCpu => match dw {
                DataWidth::Float32 => self.cpu_f32.get(ty),
                _ => self.cpu_int.get(ty),
            },
            PeClass::Cgra => match dw {
                DataWidth::Float32 => UNSUPPORTED,
                // 32-bit ALUs: same rate for all integer widths.
                _ => self.cgra.get(ty),
            },
            PeClass::Nmc => self.nmc_int8.get(ty) * Self::nmc_width_factor(dw),
        };
        if !cpo.is_finite() {
            return None;
        }
        Some(Cycles((ops as f64 * cpo).ceil() as u64))
    }

    /// Processing-only cycles for a whole kernel.
    pub fn kernel_cycles(&self, class: PeClass, k: &Kernel) -> Option<Cycles> {
        self.cycles_for_ops(class, k.ty, k.dw, k.shape.ops())
    }

    /// Per-launch fixed overhead for `class`.
    pub fn launch(&self, class: PeClass) -> Cycles {
        Cycles(self.launch_overhead.get(class))
    }

    /// Per-tile restart overhead for `class`.
    pub fn per_tile(&self, class: PeClass) -> Cycles {
        Cycles(self.tile_overhead.get(class))
    }

    /// The *original* (pre-modification) CPU cost of the paper's Table 4
    /// kernels: float softmax, float GeLU, log-amplitude FFT. Used only by
    /// the Table 4 reproduction.
    pub fn original_cpu_cycles(&self, ty: KernelType, shape: Shape) -> Cycles {
        let ops = shape.ops();
        let cpo = match ty {
            KernelType::Softmax => self.cpu_f32.softmax,
            KernelType::Gelu => self.cpu_f32.gelu,
            // log-amplitude adds a soft-float log() per output bin on top of
            // the float FFT; the blended per-op cost lands ~16.5× the
            // magnitude-only pipeline (paper Table 4: 182 M vs 11 M).
            KernelType::FftMag => self.cpu_f32.fft_mag * 16.5,
            _ => self.cpu_int.get(ty),
        };
        Cycles((ops as f64 * cpo).ceil() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataWidth::*, KernelType::*};

    fn m() -> CycleModel {
        CycleModel::heeptimize()
    }

    #[test]
    fn accelerators_beat_cpu_on_matmul() {
        let ops = 1_000_000;
        let cpu = m().cycles_for_ops(PeClass::RiscvCpu, MatMul, Int8, ops).unwrap();
        let cgra = m().cycles_for_ops(PeClass::Cgra, MatMul, Int8, ops).unwrap();
        let nmc = m().cycles_for_ops(PeClass::Nmc, MatMul, Int8, ops).unwrap();
        assert!(cgra.raw() < cpu.raw() / 5);
        assert!(nmc.raw() < cgra.raw());
    }

    #[test]
    fn nmc_width_scaling() {
        let ops = 100_000;
        let i8c = m().cycles_for_ops(PeClass::Nmc, MatMul, Int8, ops).unwrap();
        let i16c = m().cycles_for_ops(PeClass::Nmc, MatMul, Int16, ops).unwrap();
        let i32c = m().cycles_for_ops(PeClass::Nmc, MatMul, Int32, ops).unwrap();
        assert!(i16c.raw() > i8c.raw());
        assert!(i32c.raw() > i16c.raw());
        // CGRA is width-insensitive (32-bit ALUs).
        let c8 = m().cycles_for_ops(PeClass::Cgra, MatMul, Int8, ops).unwrap();
        let c32 = m().cycles_for_ops(PeClass::Cgra, MatMul, Int32, ops).unwrap();
        assert_eq!(c8, c32);
    }

    #[test]
    fn unsupported_combos_are_none() {
        assert!(m().cycles_for_ops(PeClass::Cgra, Softmax, Int8, 10).is_none());
        assert!(m().cycles_for_ops(PeClass::Nmc, FftMag, Int8, 10).is_none());
        assert!(m().cycles_for_ops(PeClass::Cgra, MatMul, Float32, 10).is_none());
        assert!(m().cycles_for_ops(PeClass::Nmc, MatMul, Float32, 10).is_none());
        // CPU runs everything.
        assert!(m().cycles_for_ops(PeClass::RiscvCpu, Softmax, Float32, 10).is_some());
    }

    #[test]
    fn table4_modification_ratios() {
        // Paper Table 4: softmax 647 M → 5 M (~129×), GeLU 8 M → 0.03 M,
        // log-FFT 182 M → 11 M (~16.5×). Check the *ratios* our model gives.
        let mm = m();
        let softmax_shape = Shape::Rowwise { rows: 97, cols: 97 };
        let orig = mm.original_cpu_cycles(Softmax, softmax_shape).raw() as f64;
        let modi = mm
            .cycles_for_ops(PeClass::RiscvCpu, Softmax, Int16, softmax_shape.ops())
            .unwrap()
            .raw() as f64;
        let ratio = orig / modi;
        assert!((50.0..200.0).contains(&ratio), "softmax ratio {ratio}");

        let fft_shape = Shape::Fft { n_fft: 256, batch: 96 };
        let orig = mm.original_cpu_cycles(FftMag, fft_shape).raw() as f64;
        let modi = mm
            .cycles_for_ops(PeClass::RiscvCpu, FftMag, Float32, fft_shape.ops())
            .unwrap()
            .raw() as f64;
        assert!((orig / modi - 16.5).abs() < 0.1, "fft ratio {}", orig / modi);

        let gelu_shape = Shape::Elementwise { n: 97 * 256, arity: 1 };
        let orig = mm.original_cpu_cycles(Gelu, gelu_shape).raw() as f64;
        let modi = mm
            .cycles_for_ops(PeClass::RiscvCpu, Gelu, Int8, gelu_shape.ops())
            .unwrap()
            .raw() as f64;
        assert!(orig / modi > 10.0, "gelu ratio {}", orig / modi);
    }

    #[test]
    fn launch_overheads_ordered() {
        // Accelerators pay configuration cost; the CPU barely any.
        assert!(m().launch(PeClass::Cgra) > m().launch(PeClass::Nmc));
        assert!(m().launch(PeClass::Nmc) > m().launch(PeClass::RiscvCpu));
    }

    #[test]
    fn kernel_cycles_matches_ops_path() {
        let k = Kernel::new(
            "mm",
            MatMul,
            Shape::MatMul { m: 97, k: 128, n: 32 },
            Int8,
        );
        assert_eq!(
            m().kernel_cycles(PeClass::Nmc, &k),
            m().cycles_for_ops(PeClass::Nmc, MatMul, Int8, k.ops())
        );
    }
}
