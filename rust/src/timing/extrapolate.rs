//! Size extrapolation over profiled cycle counts (§3.3 `G_T`).
//!
//! MEDEA's timing model "includes both directly profiled processing-only
//! cycles [and] extrapolated values for non-profiled kernel sizes". The
//! characterization harness profiles a *grid* of representative sizes per
//! (PE, kernel type, width); this module fits `cycles ≈ a·ops + b` by least
//! squares and answers queries for arbitrary sizes — exact sizes present in
//! the profile are answered from the table directly.

use crate::util::units::Cycles;
use std::collections::BTreeMap;

/// One profiled point: operation count → measured cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfilePoint {
    pub ops: u64,
    pub cycles: u64,
}

/// Least-squares linear fit through profiled (ops, cycles) points, with
/// exact-match lookup.
#[derive(Debug, Clone)]
pub struct Extrapolator {
    exact: BTreeMap<u64, u64>,
    /// slope (cycles per op)
    a: f64,
    /// intercept (fixed overhead cycles)
    b: f64,
}

impl Extrapolator {
    /// Fit from profiled points. Panics on an empty profile.
    pub fn fit(points: &[ProfilePoint]) -> Extrapolator {
        assert!(!points.is_empty(), "cannot fit an empty profile");
        let exact: BTreeMap<u64, u64> = points.iter().map(|p| (p.ops, p.cycles)).collect();

        let n = points.len() as f64;
        if points.len() == 1 {
            // Degenerate: pure proportionality through the single point.
            let p = points[0];
            let a = if p.ops == 0 { 0.0 } else { p.cycles as f64 / p.ops as f64 };
            return Extrapolator { exact, a, b: 0.0 };
        }
        let sx: f64 = points.iter().map(|p| p.ops as f64).sum();
        let sy: f64 = points.iter().map(|p| p.cycles as f64).sum();
        let sxx: f64 = points.iter().map(|p| (p.ops as f64).powi(2)).sum();
        let sxy: f64 = points.iter().map(|p| p.ops as f64 * p.cycles as f64).sum();
        let denom = n * sxx - sx * sx;
        let (a, b) = if denom.abs() < 1e-9 {
            (sy / sx.max(1.0), 0.0)
        } else {
            let a = (n * sxy - sx * sy) / denom;
            let b = (sy - a * sx) / n;
            (a, b.max(0.0)) // negative fixed overhead is unphysical
        };
        Extrapolator { exact, a, b }
    }

    /// Estimated cycles for `ops` operations.
    pub fn cycles(&self, ops: u64) -> Cycles {
        if let Some(c) = self.exact.get(&ops) {
            return Cycles(*c);
        }
        Cycles((self.a * ops as f64 + self.b).round().max(0.0) as u64)
    }

    /// Slope of the fit (marginal cycles per op).
    pub fn slope(&self) -> f64 {
        self.a
    }

    /// Intercept of the fit (estimated fixed overhead).
    pub fn intercept(&self) -> f64 {
        self.b
    }

    /// Worst relative error of the fit over its own profile points
    /// (excluding exact-match lookup) — a fit-quality diagnostic.
    pub fn max_rel_error(&self) -> f64 {
        self.exact
            .iter()
            .map(|(&ops, &cyc)| {
                let est = self.a * ops as f64 + self.b;
                (est - cyc as f64).abs() / (cyc as f64).max(1.0)
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_points_answered_from_table() {
        let e = Extrapolator::fit(&[
            ProfilePoint { ops: 100, cycles: 260 },
            ProfilePoint { ops: 200, cycles: 500 },
        ]);
        assert_eq!(e.cycles(100), Cycles(260));
        assert_eq!(e.cycles(200), Cycles(500));
    }

    #[test]
    fn linear_data_recovered() {
        // cycles = 2.5·ops + 1000
        let pts: Vec<ProfilePoint> = [1_000u64, 10_000, 100_000, 1_000_000]
            .iter()
            .map(|&ops| ProfilePoint {
                ops,
                cycles: (2.5 * ops as f64 + 1000.0) as u64,
            })
            .collect();
        let e = Extrapolator::fit(&pts);
        assert!((e.slope() - 2.5).abs() < 1e-6);
        assert!((e.intercept() - 1000.0).abs() < 1.0);
        let est = e.cycles(50_000);
        assert!((est.raw() as f64 - 126_000.0).abs() < 2.0);
        assert!(e.max_rel_error() < 1e-6);
    }

    #[test]
    fn single_point_proportional() {
        let e = Extrapolator::fit(&[ProfilePoint { ops: 1000, cycles: 3000 }]);
        assert_eq!(e.cycles(2000), Cycles(6000));
    }

    #[test]
    fn negative_intercept_clamped() {
        let e = Extrapolator::fit(&[
            ProfilePoint { ops: 100, cycles: 100 },
            ProfilePoint { ops: 200, cycles: 260 },
        ]);
        assert!(e.intercept() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "empty profile")]
    fn empty_profile_panics() {
        Extrapolator::fit(&[]);
    }
}
