//! Timing characterization stand-in (§3.1.3 `S_c` and §4.1.2 FPGA flow).
//!
//! The paper measures kernel cycle counts on an FPGA prototype; here a
//! per-PE analytical cycle model ([`cycle_model`]) plays the FPGA's role.
//! [`dma`] models L2↔LM transfers, and [`extrapolate`] reproduces the
//! paper's "extrapolated values for non-profiled kernel sizes" mechanism on
//! top of profile tables produced by [`crate::profile`].

pub mod cycle_model;
pub mod dma;
pub mod extrapolate;

pub use cycle_model::CycleModel;
pub use dma::dma_cycles;
pub use extrapolate::Extrapolator;
