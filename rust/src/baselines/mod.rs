//! The §4.4 comparison baselines.
//!
//! All baselines use double-buffer tiling throughout (§4.4: "we consistently
//! applied tiling using the double buffering strategy across all evaluated
//! methods" — for the baselines; MEDEA itself adapts). In increasing
//! sophistication:
//!
//! * [`cpu_max_vf`] — everything on the host CPU at maximum V-F.
//! * [`static_accel_max_vf`] — the single most energy-efficient accelerator
//!   for the workload at max V-F, unsupported kernels offloaded to the CPU.
//! * [`static_accel_app_dvfs`] — same assignment, plus one application-level
//!   V-F: the lowest meeting the deadline.
//! * [`coarse_grain_app_dvfs`] — per-§4.4-group energy-aware PE selection
//!   plus one application-level V-F.
//!
//! Baselines may *miss* the deadline (the CPU does at 50 ms in the paper);
//! they still return their schedule so Fig 5 can plot the violation.

pub mod schedulers;

pub use schedulers::{
    coarse_grain_app_dvfs, cpu_max_vf, static_accel_app_dvfs, static_accel_max_vf, BaselineError,
};
