//! Baseline scheduler implementations.

use crate::config::estimator::{Estimator, TilingPolicy};
use crate::ir::Workload;
use crate::manager::schedule::{Decision, Schedule};
use crate::platform::{PeId, Platform};
use crate::profile::Profiles;
use crate::timing::cycle_model::CycleModel;
use crate::util::units::{Energy, Time};

/// Baseline failure modes.
#[derive(Debug, Clone)]
pub enum BaselineError {
    NoConfig(String),
    NoGroups,
}

impl std::fmt::Display for BaselineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BaselineError::NoConfig(k) => write!(f, "kernel `{k}` cannot execute anywhere"),
            BaselineError::NoGroups => {
                write!(f, "workload has no coarse groups covering all kernels")
            }
        }
    }
}

impl std::error::Error for BaselineError {}

fn forced_db_estimator<'a>(
    platform: &'a Platform,
    profiles: &'a Profiles,
    model: &'a CycleModel,
) -> Estimator<'a> {
    Estimator::new(platform, profiles, model).with_policy(TilingPolicy::ForceDouble)
}

/// Schedule every kernel on `pe` at `vf_idx`, offloading kernels the PE
/// cannot run to the CPU (at the same V-F).
fn fixed_assignment(
    workload: &Workload,
    est: &Estimator,
    pe: PeId,
    vf_idx: usize,
) -> Result<Vec<Decision>, BaselineError> {
    let cpu = est.platform.cpu().id;
    workload
        .kernels()
        .iter()
        .enumerate()
        .map(|(i, kernel)| {
            let (use_pe, mode) = match est.best_mode(pe, kernel) {
                Some((mode, _)) => (pe, mode),
                None => {
                    let (mode, _) = est
                        .best_mode(cpu, kernel)
                        .ok_or_else(|| BaselineError::NoConfig(kernel.name.clone()))?;
                    (cpu, mode)
                }
            };
            let time = est
                .time(use_pe, kernel, vf_idx, mode)
                .ok_or_else(|| BaselineError::NoConfig(kernel.name.clone()))?;
            let energy = est.power(use_pe, kernel, vf_idx) * time;
            Ok(Decision {
                kernel: i,
                pe: use_pe,
                vf_idx,
                mode,
                time,
                energy,
            })
        })
        .collect()
}

fn to_schedule(
    name: &str,
    workload: &Workload,
    deadline: Time,
    decisions: Vec<Decision>,
) -> Schedule {
    Schedule {
        scheduler: name.to_string(),
        workload: workload.name.clone(),
        deadline,
        decisions,
        optimal: false,
    }
}

/// **CPU (MaxVF)**: homogeneous execution on the host CPU at max V-F.
pub fn cpu_max_vf(
    workload: &Workload,
    platform: &Platform,
    profiles: &Profiles,
    model: &CycleModel,
    deadline: Time,
) -> Result<Schedule, BaselineError> {
    let est = forced_db_estimator(platform, profiles, model);
    let vf_max = platform.vf.len() - 1;
    let decisions = fixed_assignment(workload, &est, platform.cpu().id, vf_max)?;
    Ok(to_schedule("cpu-maxvf", workload, deadline, decisions))
}

/// Pick the single accelerator minimizing total workload energy at max V-F
/// (with CPU offload for unsupported kernels) — the "a-priori most
/// energy-efficient accelerator" of §4.4.
fn best_static_accelerator(
    workload: &Workload,
    est: &Estimator,
) -> Result<PeId, BaselineError> {
    let vf_max = est.platform.vf.len() - 1;
    let mut best: Option<(PeId, Energy)> = None;
    for acc in est.platform.accelerators() {
        let decisions = fixed_assignment(workload, est, acc.id, vf_max)?;
        let e: Energy = decisions.iter().map(|d| d.energy).sum();
        if best.map(|(_, be)| e < be).unwrap_or(true) {
            best = Some((acc.id, e));
        }
    }
    best.map(|(pe, _)| pe)
        .ok_or_else(|| BaselineError::NoConfig("no accelerator on platform".into()))
}

/// **StaticAccel (MaxVF)**: the statically chosen accelerator at max V-F.
pub fn static_accel_max_vf(
    workload: &Workload,
    platform: &Platform,
    profiles: &Profiles,
    model: &CycleModel,
    deadline: Time,
) -> Result<Schedule, BaselineError> {
    let est = forced_db_estimator(platform, profiles, model);
    let acc = best_static_accelerator(workload, &est)?;
    let decisions = fixed_assignment(workload, &est, acc, platform.vf.len() - 1)?;
    Ok(to_schedule("staticaccel-maxvf", workload, deadline, decisions))
}

/// **StaticAccel (AppDVFS)**: the statically chosen accelerator with one
/// application-level V-F — the lowest meeting the deadline (falls back to
/// max V-F when none does).
pub fn static_accel_app_dvfs(
    workload: &Workload,
    platform: &Platform,
    profiles: &Profiles,
    model: &CycleModel,
    deadline: Time,
) -> Result<Schedule, BaselineError> {
    let est = forced_db_estimator(platform, profiles, model);
    let acc = best_static_accelerator(workload, &est)?;
    let mut last = None;
    for vf_idx in 0..platform.vf.len() {
        let decisions = fixed_assignment(workload, &est, acc, vf_idx)?;
        let total: Time = decisions.iter().map(|d| d.time).sum();
        last = Some(decisions);
        if total.raw() <= deadline.raw() {
            break;
        }
    }
    Ok(to_schedule(
        "staticaccel-appdvfs",
        workload,
        deadline,
        last.unwrap(),
    ))
}

/// **CoarseGrain (AppDVFS)**: for each §4.4 group pick the most
/// energy-efficient PE (at the candidate V-F), apply one application-level
/// V-F — the lowest meeting the deadline.
pub fn coarse_grain_app_dvfs(
    workload: &Workload,
    platform: &Platform,
    profiles: &Profiles,
    model: &CycleModel,
    deadline: Time,
) -> Result<Schedule, BaselineError> {
    if !workload.groups_cover_all() {
        return Err(BaselineError::NoGroups);
    }
    let est = forced_db_estimator(platform, profiles, model);
    let cpu = platform.cpu().id;

    let mut last: Option<Vec<Decision>> = None;
    for vf_idx in 0..platform.vf.len() {
        let mut decisions: Vec<Decision> = Vec::with_capacity(workload.len());
        for group in workload.groups() {
            // Evaluate each candidate PE for the whole group at this V-F.
            let mut best: Option<(Energy, Vec<Decision>)> = None;
            for pe in platform.pe_ids() {
                let mut ds = Vec::new();
                let mut e_total = Energy::ZERO;
                let mut ok = true;
                for ki in group.range.clone() {
                    let kernel = &workload.kernels()[ki];
                    let (use_pe, mode) = match est.best_mode(pe, kernel) {
                        Some((mode, _)) => (pe, mode),
                        None => match est.best_mode(cpu, kernel) {
                            Some((mode, _)) => (cpu, mode),
                            None => {
                                ok = false;
                                break;
                            }
                        },
                    };
                    let Some(time) = est.time(use_pe, kernel, vf_idx, mode) else {
                        ok = false;
                        break;
                    };
                    let energy = est.power(use_pe, kernel, vf_idx) * time;
                    e_total += energy;
                    ds.push(Decision {
                        kernel: ki,
                        pe: use_pe,
                        vf_idx,
                        mode,
                        time,
                        energy,
                    });
                }
                if ok && best.as_ref().map(|(be, _)| e_total < *be).unwrap_or(true) {
                    best = Some((e_total, ds));
                }
            }
            let (_, ds) = best.ok_or_else(|| BaselineError::NoConfig(group.name.clone()))?;
            decisions.extend(ds);
        }
        decisions.sort_by_key(|d| d.kernel);
        let total: Time = decisions.iter().map(|d| d.time).sum();
        last = Some(decisions);
        if total.raw() <= deadline.raw() {
            break;
        }
    }
    Ok(to_schedule(
        "coarsegrain-appdvfs",
        workload,
        deadline,
        last.unwrap(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tsd::{tsd_core, TsdParams};
    use crate::manager::medea::Medea;
    use crate::platform::heeptimize::{heeptimize, CPU};
    use crate::profile::characterize;

    struct Ctx {
        platform: Platform,
        profiles: Profiles,
        model: CycleModel,
        workload: Workload,
    }

    fn ctx() -> Ctx {
        let platform = heeptimize();
        let model = CycleModel::heeptimize();
        let profiles = characterize(&platform, &model);
        Ctx {
            workload: tsd_core(&TsdParams::default()),
            platform,
            profiles,
            model,
        }
    }

    #[test]
    fn cpu_baseline_is_all_cpu_and_misses_tight_deadline() {
        let c = ctx();
        let s = cpu_max_vf(
            &c.workload,
            &c.platform,
            &c.profiles,
            &c.model,
            Time::from_ms(50.0),
        )
        .unwrap();
        assert!(s.decisions.iter().all(|d| d.pe == CPU));
        // Paper §5.1: the CPU cannot meet the 50 ms deadline.
        assert!(!s.meets_deadline(), "active {}", s.active_time().as_ms());
        s.validate(&c.workload, &c.platform).unwrap();
    }

    #[test]
    fn static_accel_uses_one_accelerator_plus_cpu() {
        let c = ctx();
        let s = static_accel_max_vf(
            &c.workload,
            &c.platform,
            &c.profiles,
            &c.model,
            Time::from_ms(200.0),
        )
        .unwrap();
        let accel_pes: std::collections::BTreeSet<_> = s
            .decisions
            .iter()
            .map(|d| d.pe)
            .filter(|&p| p != CPU)
            .collect();
        assert_eq!(accel_pes.len(), 1, "must use exactly one accelerator");
        assert!(s.meets_deadline());
    }

    #[test]
    fn app_dvfs_lowers_energy_vs_maxvf() {
        let c = ctx();
        let d = Time::from_ms(200.0);
        let max =
            static_accel_max_vf(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        let dvfs =
            static_accel_app_dvfs(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        assert!(dvfs.meets_deadline());
        assert!(
            dvfs.active_energy().raw() < max.active_energy().raw(),
            "AppDVFS {} !< MaxVF {}",
            dvfs.active_energy().as_uj(),
            max.active_energy().as_uj()
        );
        // One V-F throughout.
        let vf0 = dvfs.decisions[0].vf_idx;
        assert!(dvfs.decisions.iter().all(|d| d.vf_idx == vf0));
    }

    #[test]
    fn paper_energy_ordering_holds() {
        // Fig 5 ordering at 200 ms: CPU > StaticAccel(MaxVF) >
        // StaticAccel(AppDVFS) > CoarseGrain(AppDVFS) > MEDEA.
        let c = ctx();
        let d = Time::from_ms(200.0);
        let e = |s: &Schedule| s.total_energy(&c.platform).as_uj();
        let cpu = cpu_max_vf(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        let sa = static_accel_max_vf(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        let sad =
            static_accel_app_dvfs(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        let cg =
            coarse_grain_app_dvfs(&c.workload, &c.platform, &c.profiles, &c.model, d).unwrap();
        let medea = Medea::new(&c.platform, &c.profiles, &c.model)
            .schedule(&c.workload, d)
            .unwrap();
        assert!(e(&cpu) > e(&sa), "cpu {} !> sa {}", e(&cpu), e(&sa));
        assert!(e(&sa) > e(&sad), "sa {} !> sad {}", e(&sa), e(&sad));
        assert!(e(&sad) > e(&cg), "sad {} !> cg {}", e(&sad), e(&cg));
        assert!(e(&cg) > e(&medea), "cg {} !> medea {}", e(&cg), e(&medea));
    }

    #[test]
    fn coarse_grain_meets_deadlines() {
        let c = ctx();
        for ms in [50.0, 200.0, 1000.0] {
            let s = coarse_grain_app_dvfs(
                &c.workload,
                &c.platform,
                &c.profiles,
                &c.model,
                Time::from_ms(ms),
            )
            .unwrap();
            assert!(s.meets_deadline(), "{ms} ms: active {}", s.active_time().as_ms());
            s.validate(&c.workload, &c.platform).unwrap();
        }
    }
}
