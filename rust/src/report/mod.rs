//! Output helpers for experiment drivers: render a table to the terminal
//! and optionally persist CSV/markdown under `results/`.

use crate::util::table::Table;
use std::path::Path;

/// Output format selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Text,
    Markdown,
    Csv,
}

impl Format {
    pub fn from_name(s: &str) -> Option<Format> {
        match s {
            "text" => Some(Format::Text),
            "md" | "markdown" => Some(Format::Markdown),
            "csv" => Some(Format::Csv),
            _ => None,
        }
    }
}

/// Render `table` in `format`.
pub fn render(table: &Table, format: Format) -> String {
    match format {
        Format::Text => table.to_text(),
        Format::Markdown => table.to_markdown(),
        Format::Csv => table.to_csv(),
    }
}

/// Print to stdout and, when `out_dir` is set, persist as
/// `<out_dir>/<name>.csv` + `.md`.
pub fn emit(table: &Table, name: &str, format: Format, out_dir: Option<&Path>) {
    println!("{}", render(table, format));
    if let Some(dir) = out_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("warning: cannot create {dir:?}: {e}");
            return;
        }
        for (ext, fmt) in [("csv", Format::Csv), ("md", Format::Markdown)] {
            let path = dir.join(format!("{name}.{ext}"));
            if let Err(e) = std::fs::write(&path, render(table, fmt)) {
                eprintln!("warning: cannot write {path:?}: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_round_trip() {
        assert_eq!(Format::from_name("csv"), Some(Format::Csv));
        assert_eq!(Format::from_name("md"), Some(Format::Markdown));
        assert_eq!(Format::from_name("nope"), None);
    }

    #[test]
    fn emit_writes_files() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("medea_report_test");
        emit(&t, "t1", Format::Text, Some(&dir));
        assert!(dir.join("t1.csv").exists());
        assert!(dir.join("t1.md").exists());
    }
}
