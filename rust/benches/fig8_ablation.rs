//! Bench: regenerate Fig 8 + Table 6 (feature ablations) and time the
//! ablated managers (each solves its own MCKP variant).

use medea::exp::{fig8, ExpContext};
use medea::manager::medea::MedeaFeatures;
use medea::util::bench::Bencher;
use medea::util::units::Time;

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();
    let d = Time::from_ms(200.0);
    b.bench("ablation/full@200ms", || {
        ctx.medea_with(MedeaFeatures::default())
            .schedule(&ctx.workload, d)
            .unwrap()
    });
    b.bench("ablation/wo-kerdvfs@200ms", || {
        ctx.medea_with(MedeaFeatures::without_kernel_dvfs())
            .schedule(&ctx.workload, d)
            .unwrap()
    });
    b.bench("ablation/wo-kersched@200ms", || {
        ctx.medea_with(MedeaFeatures::without_kernel_sched())
            .schedule(&ctx.workload, d)
            .unwrap()
    });
    b.bench("ablation/wo-adaptile@200ms", || {
        ctx.medea_with(MedeaFeatures::without_adaptive_tiling())
            .schedule(&ctx.workload, d)
            .unwrap()
    });

    println!("\n{}", fig8::table6(&ctx).to_text());
    println!("{}", fig8::run(&ctx).to_text());
    b.finish("fig8_ablation");
}
