//! Bench: MCKP solver performance comparison (the optimization hot path) on
//! both the real MEDEA instance and synthetic instances of growing size.

use medea::config::{ConfigSpace, Estimator};
use medea::exp::ExpContext;
use medea::solver::{
    random_instance, BranchBound, DpSolver, GreedySolver, Instance, Item, LagrangeSolver,
    McKpSolver,
};
use medea::util::bench::Bencher;
use medea::util::rng::Rng;

fn medea_instance(ctx: &ExpContext, deadline_s: f64) -> Instance {
    let est = Estimator::new(&ctx.platform, &ctx.profiles, &ctx.model);
    let space = ConfigSpace::enumerate(&ctx.workload, &est);
    Instance {
        groups: space
            .per_kernel
            .iter()
            .map(|cs| {
                cs.iter()
                    .map(|c| Item {
                        time: c.time.raw(),
                        energy: c.energy.raw(),
                    })
                    .collect()
            })
            .collect(),
        deadline: deadline_s,
    }
}

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();

    let inst = medea_instance(&ctx, 0.200);
    println!(
        "MEDEA instance: {} groups, {} items total",
        inst.groups.len(),
        inst.groups.iter().map(|g| g.len()).sum::<usize>()
    );
    b.bench("mckp/dp/tsd@200ms", || {
        DpSolver::default().solve(&inst).unwrap().total_energy
    });
    b.bench("mckp/bb/tsd@200ms", || {
        BranchBound::default().solve(&inst).unwrap().total_energy
    });
    b.bench("mckp/lagrange/tsd@200ms", || {
        LagrangeSolver::default().solve(&inst).unwrap().total_energy
    });
    b.bench("mckp/greedy/tsd@200ms", || {
        GreedySolver.solve(&inst).unwrap().total_energy
    });

    // Scaling study on synthetic instances.
    for groups in [100usize, 400, 1600] {
        let mut rng = Rng::new(groups as u64);
        let synth = random_instance(&mut rng, groups, 12);
        b.bench(&format!("mckp/dp/synthetic-{groups}g"), || {
            DpSolver::default().solve(&synth).map(|s| s.total_energy)
        });
        b.bench(&format!("mckp/greedy/synthetic-{groups}g"), || {
            GreedySolver.solve(&synth).map(|s| s.total_energy)
        });
    }

    // Enumeration (config-space build) cost.
    b.bench("config-space/enumerate-tsd", || {
        let est = Estimator::new(&ctx.platform, &ctx.profiles, &ctx.model);
        ConfigSpace::enumerate(&ctx.workload, &est).total_configs()
    });

    b.finish("solver_perf");
}
