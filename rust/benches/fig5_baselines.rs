//! Bench: regenerate Fig 5 (MEDEA vs the four baselines × three deadlines)
//! and time each scheduler end-to-end (enumeration + solve + extraction).
//!
//! `cargo bench --bench fig5_baselines` (set MEDEA_BENCH_FAST=1 to trim).

use medea::baselines::{
    coarse_grain_app_dvfs, cpu_max_vf, static_accel_app_dvfs, static_accel_max_vf,
};
use medea::exp::{fig5, ExpContext};
use medea::util::bench::Bencher;
use medea::util::units::Time;

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();
    let d = Time::from_ms(200.0);

    let (w, p, pr, m) = (&ctx.workload, &ctx.platform, &ctx.profiles, &ctx.model);
    b.bench("scheduler/cpu-maxvf@200ms", || {
        cpu_max_vf(w, p, pr, m, d).unwrap()
    });
    b.bench("scheduler/staticaccel-maxvf@200ms", || {
        static_accel_max_vf(w, p, pr, m, d).unwrap()
    });
    b.bench("scheduler/staticaccel-appdvfs@200ms", || {
        static_accel_app_dvfs(w, p, pr, m, d).unwrap()
    });
    b.bench("scheduler/coarsegrain-appdvfs@200ms", || {
        coarse_grain_app_dvfs(w, p, pr, m, d).unwrap()
    });
    b.bench("scheduler/medea-dp@200ms", || {
        ctx.medea().schedule(w, d).unwrap()
    });

    println!("\n{}", fig5::run(&ctx).to_text());
    b.finish("fig5_baselines");
}
