//! Bench: regenerate Fig 7 (CGRA/Carus ratio vs V-F) and time the
//! estimator over the matmul subset (the `G_T`/`G_P` hot path).

use medea::config::estimator::Estimator;
use medea::exp::{fig7, ExpContext};
use medea::ir::tsd::{tsd_matmul_subset, TsdParams};
use medea::platform::heeptimize::{CARUS, CGRA};
use medea::util::bench::Bencher;

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();
    let subset = tsd_matmul_subset(&TsdParams::default());
    let est = Estimator::new(&ctx.platform, &ctx.profiles, &ctx.model);

    b.bench("estimator/matmul-subset-both-accels", || {
        let mut acc = 0.0f64;
        for k in subset.kernels() {
            for pe in [CGRA, CARUS] {
                let (mode, _) = est.best_mode(pe, k).unwrap();
                for vf in 0..ctx.platform.vf.len() {
                    acc += est.energy(pe, k, vf, mode).unwrap().raw();
                }
            }
        }
        acc
    });
    b.bench("fig7/full-table", || fig7::rows(&ctx).len());

    println!("\n{}", fig7::run(&ctx).to_text());
    b.finish("fig7_crossover");
}
