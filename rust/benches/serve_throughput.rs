//! Bench: serving-path latency and pool throughput.
//!
//! Two comparisons back the serve subsystem's existence:
//!
//! 1. **cold vs warm request path** — the pre-atlas coordinator ran a full
//!    MCKP DP solve for every previously unseen deadline; the atlas resolves
//!    the same request with an `O(log n)` binary search. Both are measured
//!    over a rotating set of distinct deadlines (so caches cannot hide the
//!    solve) and the speedup is reported — the acceptance bar is ≥ 10×.
//! 2. **pool load test** — a burst of requests with a mixed deadline
//!    profile (including infeasible ones that must shed) through the
//!    multi-worker pool, reporting throughput and latency percentiles.
//!
//! Results are printed and written to `BENCH_serve.json`.
//!
//! `cargo bench --bench serve_throughput` (set MEDEA_BENCH_FAST=1 to trim).

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::json_obj;
use medea::serve::{
    AtlasConfig, PoolConfig, Rejection, ScheduleAtlas, ServeMetrics, ServePool, Ticket,
};
use medea::telemetry::{
    scrape, FlightConfig, FlightRecorder, MetricsServer, SloEngine, SloSpec, SloTicker,
    TelemetryConfig,
};
use medea::util::bench::{write_bench_json, Bencher};
use medea::util::json::Json;
use medea::util::units::Time;
use std::cell::Cell;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One pool load run: burst-submit a mixed-deadline profile (1-in-8 requests
/// below the feasibility floor, which must shed with a typed rejection).
struct PoolRun {
    served: usize,
    shed_floor: u64,
    elapsed: Duration,
    rps: f64,
    metrics: ServeMetrics,
    snapshot: Json,
}

/// `observed = true` runs the worst-case "someone is watching" configuration:
/// a 65536-event trace ring (which makes every dispatch also emit per-kernel
/// spans), the SLO evaluator on a 250 ms tick with an armed flight recorder,
/// and a live exposition endpoint with a scraper thread polling it every
/// 25 ms for the whole burst. The energy attribution ledger is on in BOTH
/// configurations — it has no switch — so the dark run is the true always-on
/// baseline and the 0.97 gate below prices the ring + spans + scrapes only.
fn run_pool_load(atlas: &ScheduleAtlas, requests: usize, observed: bool) -> PoolRun {
    let floor = atlas.floor().as_ms();
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 4,
            queue_capacity: requests,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            telemetry: TelemetryConfig {
                trace_events: if observed { 65_536 } else { 0 },
            },
            ..PoolConfig::default()
        },
        atlas.clone(),
    )
    .unwrap();

    let (server, _ticker, scraper, stop) = if observed {
        let postmortem_dir = std::env::temp_dir()
            .join(format!("medea-bench-postmortems-{}", std::process::id()));
        let flight = FlightRecorder::new(FlightConfig {
            dir: postmortem_dir,
            ..FlightConfig::default()
        })
        .unwrap();
        let engine = SloEngine::new(
            SloSpec::default(),
            Arc::clone(pool.telemetry()),
            pool.trace().map(Arc::clone),
            Some(Arc::new(flight)),
        );
        let ticker = SloTicker::start(engine.clone(), Duration::from_millis(250));
        let server = MetricsServer::start_with(
            "127.0.0.1:0",
            pool.telemetry().clone(),
            Some(engine),
            Some(pool.readiness_probe()),
        )
        .unwrap();
        let addr = server.addr().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let scraper = std::thread::spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                let _ = scrape(&addr);
                std::thread::sleep(Duration::from_millis(25));
            }
        });
        (Some(server), Some(ticker), Some(scraper), Some(stop))
    } else {
        (None, None, None, None)
    };

    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let load_start = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(requests);
    let mut shed_floor = 0u64;
    for i in 0..requests {
        // 1-in-8 requests are below the feasibility floor.
        let d = if i % 8 == 7 {
            Time::from_ms(floor * 0.5)
        } else {
            Time::from_ms(floor * (1.05 + 2.3 * ((i % 7) as f64)))
        };
        match pool.submit(gen.next_window(), d) {
            Ok(t) => tickets.push(t),
            Err(Rejection::BelowFloor { .. }) => shed_floor += 1,
            Err(other) => panic!("unexpected rejection: {other}"),
        }
    }
    let served = tickets.len();
    for t in tickets {
        t.wait().unwrap();
    }
    let elapsed = load_start.elapsed();

    if let Some(stop) = &stop {
        stop.store(true, Ordering::Relaxed);
    }
    if let Some(handle) = scraper {
        handle.join().unwrap();
    }
    drop(server);

    let registry = Arc::clone(pool.telemetry());
    let metrics = pool.shutdown();
    let snapshot = registry.snapshot().to_json();
    assert_eq!(metrics.aggregate.requests as usize, served);
    assert_eq!(metrics.shed_below_floor, shed_floor);
    assert_eq!(metrics.aggregate.deadline_misses, 0);
    PoolRun {
        served,
        shed_floor,
        elapsed,
        rps: served as f64 / elapsed.as_secs_f64(),
        metrics,
        snapshot,
    }
}

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();

    let atlas_cfg = AtlasConfig::default();
    let t0 = Instant::now();
    let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &atlas_cfg).unwrap();
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "atlas: {} knots, floor {:.1} ms, built in {:.0} ms\n",
        atlas.len(),
        atlas.floor().as_ms(),
        build_ms
    );

    // Rotating distinct deadlines spanning the whole feasible range, so the
    // cold path re-solves every time (as the old per-deadline cache would
    // on its compulsory miss) and the warm path exercises varied knots.
    let floor = atlas.floor().as_ms();
    let deadlines: Vec<Time> = (0..64)
        .map(|i| Time::from_ms(floor * (1.02 + 0.35 * i as f64)))
        .collect();

    let idx = Cell::new(0usize);
    let cold = b
        .bench("serve/cold-miss (full DP solve)", || {
            let d = deadlines[idx.get() % deadlines.len()];
            idx.set(idx.get() + 1);
            ctx.medea().schedule(&ctx.workload, d * 0.97).unwrap().decisions.len()
        })
        .mean;

    let idx = Cell::new(0usize);
    let warm = b
        .bench("serve/warm atlas resolve", || {
            let d = deadlines[idx.get() % deadlines.len()];
            idx.set(idx.get() + 1);
            atlas.resolve(d).unwrap().decisions.len()
        })
        .mean;

    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-12);
    println!(
        "\nsteady-state speedup: {speedup:.0}x (cold {:.3} ms, warm {:.3} us)",
        cold.as_secs_f64() * 1e3,
        warm.as_secs_f64() * 1e6
    );
    assert!(
        speedup >= 10.0,
        "warm atlas path must be >= 10x faster than the cold DP path, got {speedup:.1}x"
    );

    // Pool load test, run both dark (telemetry registry only, the always-on
    // baseline) and observed (trace ring + live scrapes). Best-of-3 each to
    // shave scheduler noise before gating the overhead ratio.
    let requests = if std::env::var("MEDEA_BENCH_FAST").is_ok() { 128 } else { 512 };
    let mut base = run_pool_load(&atlas, requests, false);
    let mut observed = run_pool_load(&atlas, requests, true);
    for _ in 0..2 {
        let run = run_pool_load(&atlas, requests, false);
        if run.rps > base.rps {
            base = run;
        }
        let run = run_pool_load(&atlas, requests, true);
        if run.rps > observed.rps {
            observed = run;
        }
    }
    println!(
        "\npool: {} served + {} shed in {:.1} ms ({:.0} req/s)  {}",
        base.served,
        base.shed_floor,
        base.elapsed.as_secs_f64() * 1e3,
        base.rps,
        base.metrics.summary()
    );
    let overhead_ratio = observed.rps / base.rps.max(1e-9);
    println!(
        "telemetry overhead: base {:.0} req/s, observed (trace + SLO + live scrapes) {:.0} req/s \
         ({:.1}% delta)",
        base.rps,
        observed.rps,
        (1.0 - overhead_ratio) * 100.0
    );
    assert!(
        overhead_ratio >= 0.97,
        "observed telemetry (trace ring + SLO evaluator + scraping) must cost <= 3% rps, \
         got base {:.0} vs observed {:.0} req/s",
        base.rps,
        observed.rps
    );

    // Machine-readable summary, with the observed run's registry snapshot
    // attached so the artifact carries the same data a live scrape would —
    // ledger included, so `medea energy-report BENCH_serve.json` works.
    let out = json_obj! {
        "atlas_knots" => atlas.len(),
        "atlas_build_ms" => build_ms,
        "atlas_floor_ms" => floor,
        "cold_dp_us" => cold.as_secs_f64() * 1e6,
        "warm_atlas_us" => warm.as_secs_f64() * 1e6,
        "speedup" => speedup,
        "pool" => json_obj! {
            "workers" => 4u64,
            "served" => base.served,
            "shed_below_floor" => base.shed_floor,
            "elapsed_ms" => base.elapsed.as_secs_f64() * 1e3,
            "reqs_per_sec" => base.rps,
            "host_p50_us" => base.metrics.p50().as_secs_f64() * 1e6,
            "host_p99_us" => base.metrics.p99().as_secs_f64() * 1e6,
        },
        "telemetry_overhead" => json_obj! {
            "base_reqs_per_sec" => base.rps,
            "observed_reqs_per_sec" => observed.rps,
            "ratio" => overhead_ratio,
        },
    };
    write_bench_json("BENCH_serve.json", out, Some(observed.snapshot))
        .expect("write BENCH_serve.json");

    b.finish("serve_throughput");
}
