//! Bench: serving-path latency and pool throughput.
//!
//! Two comparisons back the serve subsystem's existence:
//!
//! 1. **cold vs warm request path** — the pre-atlas coordinator ran a full
//!    MCKP DP solve for every previously unseen deadline; the atlas resolves
//!    the same request with an `O(log n)` binary search. Both are measured
//!    over a rotating set of distinct deadlines (so caches cannot hide the
//!    solve) and the speedup is reported — the acceptance bar is ≥ 10×.
//! 2. **pool load test** — a burst of requests with a mixed deadline
//!    profile (including infeasible ones that must shed) through the
//!    multi-worker pool, reporting throughput and latency percentiles.
//!
//! Results are printed and written to `BENCH_serve.json`.
//!
//! `cargo bench --bench serve_throughput` (set MEDEA_BENCH_FAST=1 to trim).

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::json_obj;
use medea::serve::{AtlasConfig, PoolConfig, Rejection, ScheduleAtlas, ServePool, Ticket};
use medea::util::bench::Bencher;
use medea::util::units::Time;
use std::cell::Cell;
use std::path::PathBuf;
use std::time::Instant;

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();

    let atlas_cfg = AtlasConfig::default();
    let t0 = Instant::now();
    let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &atlas_cfg).unwrap();
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "atlas: {} knots, floor {:.1} ms, built in {:.0} ms\n",
        atlas.len(),
        atlas.floor().as_ms(),
        build_ms
    );

    // Rotating distinct deadlines spanning the whole feasible range, so the
    // cold path re-solves every time (as the old per-deadline cache would
    // on its compulsory miss) and the warm path exercises varied knots.
    let floor = atlas.floor().as_ms();
    let deadlines: Vec<Time> = (0..64)
        .map(|i| Time::from_ms(floor * (1.02 + 0.35 * i as f64)))
        .collect();

    let idx = Cell::new(0usize);
    let cold = b
        .bench("serve/cold-miss (full DP solve)", || {
            let d = deadlines[idx.get() % deadlines.len()];
            idx.set(idx.get() + 1);
            ctx.medea().schedule(&ctx.workload, d * 0.97).unwrap().decisions.len()
        })
        .mean;

    let idx = Cell::new(0usize);
    let warm = b
        .bench("serve/warm atlas resolve", || {
            let d = deadlines[idx.get() % deadlines.len()];
            idx.set(idx.get() + 1);
            atlas.resolve(d).unwrap().decisions.len()
        })
        .mean;

    let speedup = cold.as_secs_f64() / warm.as_secs_f64().max(1e-12);
    println!(
        "\nsteady-state speedup: {speedup:.0}x (cold {:.3} ms, warm {:.3} us)",
        cold.as_secs_f64() * 1e3,
        warm.as_secs_f64() * 1e6
    );
    assert!(
        speedup >= 10.0,
        "warm atlas path must be >= 10x faster than the cold DP path, got {speedup:.1}x"
    );

    // Pool load test: burst-submit a mixed-deadline profile; a slice of the
    // traffic is infeasible and must shed with a typed rejection.
    let requests = if std::env::var("MEDEA_BENCH_FAST").is_ok() { 128 } else { 512 };
    let pool = ServePool::start(PoolConfig {
        workers: 4,
        queue_capacity: requests,
        artifact_dir: PathBuf::from("/nonexistent-artifacts"),
        ..PoolConfig::default()
    })
    .unwrap();
    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let load_start = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(requests);
    let mut shed_floor = 0u64;
    for i in 0..requests {
        // 1-in-8 requests are below the feasibility floor.
        let d = if i % 8 == 7 {
            Time::from_ms(floor * 0.5)
        } else {
            Time::from_ms(floor * (1.05 + 2.3 * ((i % 7) as f64)))
        };
        match pool.submit(gen.next_window(), d) {
            Ok(t) => tickets.push(t),
            Err(Rejection::BelowFloor { .. }) => shed_floor += 1,
            Err(other) => panic!("unexpected rejection: {other}"),
        }
    }
    let served = tickets.len();
    for t in tickets {
        t.wait().unwrap();
    }
    let elapsed = load_start.elapsed();
    let metrics = pool.shutdown();
    assert_eq!(metrics.aggregate.requests as usize, served);
    assert_eq!(metrics.shed_below_floor, shed_floor);
    assert_eq!(metrics.aggregate.deadline_misses, 0);
    let rps = served as f64 / elapsed.as_secs_f64();
    println!(
        "\npool: {} served + {} shed in {:.1} ms ({:.0} req/s)  {}",
        served,
        shed_floor,
        elapsed.as_secs_f64() * 1e3,
        rps,
        metrics.summary()
    );

    // Machine-readable summary.
    let out = json_obj! {
        "atlas_knots" => atlas.len(),
        "atlas_build_ms" => build_ms,
        "atlas_floor_ms" => floor,
        "cold_dp_us" => cold.as_secs_f64() * 1e6,
        "warm_atlas_us" => warm.as_secs_f64() * 1e6,
        "speedup" => speedup,
        "pool" => json_obj! {
            "workers" => 4u64,
            "served" => served,
            "shed_below_floor" => shed_floor,
            "elapsed_ms" => elapsed.as_secs_f64() * 1e3,
            "reqs_per_sec" => rps,
            "host_p50_us" => metrics.p50().as_secs_f64() * 1e6,
            "host_p99_us" => metrics.p99().as_secs_f64() * 1e6,
        },
    };
    std::fs::write("BENCH_serve.json", out.to_pretty()).expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json");

    b.finish("serve_throughput");
}
