//! Bench: regenerate Tables 2–5 and time the characterization campaign +
//! the simulator (the substrate hot paths behind every table).

use medea::exp::{tables, ExpContext};
use medea::profile::characterize;
use medea::sim::replay::simulate;
use medea::util::bench::Bencher;
use medea::util::units::Time;

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();

    b.bench("characterize/heeptimize-full-campaign", || {
        characterize(&ctx.platform, &ctx.model).timing_entry_count()
    });

    let schedule = ctx
        .medea()
        .schedule(&ctx.workload, Time::from_ms(200.0))
        .unwrap();
    b.bench("sim/replay-tsd-core@200ms", || {
        simulate(&ctx.workload, &ctx.platform, &ctx.model, &schedule).events
    });

    println!("\n{}", tables::table2(&ctx).to_text());
    println!("{}", tables::table3(&ctx).to_text());
    println!("{}", tables::table4(&ctx).to_text());
    println!("{}", tables::table5(&ctx).to_text());
    b.finish("tables");
}
