//! Bench: batched vs solo dispatch throughput on the stub backend.
//!
//! The batch admission layer exists to amortize per-dispatch overhead
//! (simulated on-device run + PJRT invocation) across compatible requests.
//! This bench drives the same burst — identical lax deadlines, so every
//! request resolves to one atlas knot — through two pools that differ only
//! in `BatchConfig`:
//!
//! * **solo**  — `max_batch = 1`, the legacy one-dispatch-per-request path;
//! * **batch** — `max_batch = 8`, opportunistic coalescing (no fill window).
//!
//! Acceptance bar: ≥ 2× requests/sec at batch size 8, with zero deadline
//! misses in either run. Results are printed and written to
//! `BENCH_batch.json`.
//!
//! `cargo bench --bench batch_throughput` (set MEDEA_BENCH_FAST=1 to trim).

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::json_obj;
use medea::serve::{
    AtlasConfig, BatchConfig, PoolConfig, ScheduleAtlas, ServeMetrics, ServePool, Ticket,
};
use medea::util::bench::write_bench_json;
use medea::util::json::Json;
use medea::util::units::Time;
use std::path::PathBuf;
use std::time::{Duration, Instant};

struct LoadResult {
    elapsed: Duration,
    rps: f64,
    metrics: ServeMetrics,
    snapshot: Json,
}

fn run_load(
    atlas: &ScheduleAtlas,
    batch: BatchConfig,
    requests: usize,
    deadline: Time,
) -> LoadResult {
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 2,
            queue_capacity: requests,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            batch,
            ..PoolConfig::default()
        },
        atlas.clone(),
    )
    .expect("start pool");
    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let start = Instant::now();
    let tickets: Vec<Ticket> = (0..requests)
        .map(|_| pool.submit(gen.next_window(), deadline).expect("admit"))
        .collect();
    for t in tickets {
        let out = t.wait().expect("serve");
        assert!(out.sim.deadline_met, "deadline violated under load");
    }
    let elapsed = start.elapsed();
    let registry = std::sync::Arc::clone(pool.telemetry());
    let metrics = pool.shutdown();
    let snapshot = registry.snapshot().to_json();
    assert_eq!(metrics.aggregate.requests as usize, requests);
    assert_eq!(
        metrics.aggregate.deadline_misses, 0,
        "batched admission must keep zero deadline misses"
    );
    LoadResult {
        elapsed,
        rps: requests as f64 / elapsed.as_secs_f64(),
        metrics,
        snapshot,
    }
}

fn main() {
    let fast = std::env::var("MEDEA_BENCH_FAST").is_ok();
    let requests = if fast { 256 } else { 1024 };

    let ctx = ExpContext::paper();
    let atlas = ScheduleAtlas::build(
        &ctx.medea(),
        &ctx.workload,
        &AtlasConfig {
            relax_factor: 8.0,
            growth: 1.4,
            refine_rel_energy: 0.02,
            max_knots: 48,
            ..AtlasConfig::default()
        },
    )
    .expect("atlas build");
    // Lax enough that even a full batch of the energy-minimal knot fits:
    // hi ≤ relax_factor·floor, so sim_time·scale(8) < 8·floor·6.95 < 64·floor.
    let deadline = atlas.floor() * 64.0;
    println!(
        "atlas: {} knots, floor {:.1} ms; load: {} requests at deadline {:.0} ms\n",
        atlas.len(),
        atlas.floor().as_ms(),
        requests,
        deadline.as_ms()
    );

    let solo = run_load(&atlas, BatchConfig::solo(), requests, deadline);
    println!(
        "solo  (max_batch=1): {:>8.1} req/s in {:.1} ms  {}",
        solo.rps,
        solo.elapsed.as_secs_f64() * 1e3,
        solo.metrics.summary()
    );

    let batched = run_load(
        &atlas,
        BatchConfig {
            max_batch: 8,
            ..BatchConfig::default()
        },
        requests,
        deadline,
    );
    println!(
        "batch (max_batch=8): {:>8.1} req/s in {:.1} ms  {}",
        batched.rps,
        batched.elapsed.as_secs_f64() * 1e3,
        batched.metrics.summary()
    );
    let hist = batched.metrics.batch_histogram().to_vec();
    println!("batch-size histogram (dispatches of size 1..): {hist:?}");

    let speedup = batched.rps / solo.rps.max(1e-9);
    println!("\nbatched vs solo dispatch: {speedup:.2}x requests/sec");
    assert!(
        batched.metrics.batched_requests() > 0,
        "load burst formed no batches — amortization never engaged"
    );
    assert!(
        speedup >= 2.0,
        "batched dispatch must deliver >= 2x requests/sec at batch size 8, got {speedup:.2}x"
    );

    let out = json_obj! {
        "requests" => requests,
        "deadline_ms" => deadline.as_ms(),
        "atlas_knots" => atlas.len(),
        "solo" => json_obj! {
            "reqs_per_sec" => solo.rps,
            "elapsed_ms" => solo.elapsed.as_secs_f64() * 1e3,
            "p50_us" => solo.metrics.p50().as_secs_f64() * 1e6,
            "p99_us" => solo.metrics.p99().as_secs_f64() * 1e6,
        },
        "batch8" => json_obj! {
            "reqs_per_sec" => batched.rps,
            "elapsed_ms" => batched.elapsed.as_secs_f64() * 1e3,
            "p50_us" => batched.metrics.p50().as_secs_f64() * 1e6,
            "p99_us" => batched.metrics.p99().as_secs_f64() * 1e6,
            "batched_requests" => batched.metrics.batched_requests(),
            "solo_requests" => batched.metrics.solo_requests(),
            "batch_hist" => Json::Arr(hist.iter().map(|&n| Json::from(n)).collect()),
        },
        "speedup" => speedup,
    };
    // Attach the batched run's registry snapshot so the CI artifact carries
    // the full telemetry view (histograms included), not just the summary.
    write_bench_json("BENCH_batch.json", out, Some(batched.snapshot))
        .expect("write BENCH_batch.json");
}
