//! Bench: fleet routing overhead and hot-swap latency.
//!
//! The fleet layer adds two `O(log n)` map walks (name alias → key → entry)
//! in front of the atlas binary search; this bench measures that full
//! request-path resolution against the raw single-atlas lookup it wraps,
//! plus the energy-budget path and the cost of an atomic registry publish
//! (the hot-swap primitive). Results are printed and written to
//! `BENCH_fleet.json`.
//!
//! `cargo bench --bench fleet_lookup` (set MEDEA_BENCH_FAST=1 to trim).

use medea::fleet::{Demand, EnergyAtlasConfig, FleetConfig, FleetEntry, FleetRegistry};
use medea::json_obj;
use medea::serve::AtlasConfig;
use medea::util::bench::Bencher;
use std::cell::Cell;
use std::time::Instant;

const PLATFORMS: [&str; 2] = ["heeptimize", "heeptimize-hp"];
const WORKLOADS: [&str; 2] = ["tsd-core", "tsd-small"];

fn bench_cfg() -> FleetConfig {
    FleetConfig {
        atlas: AtlasConfig {
            relax_factor: 8.0,
            growth: 1.5,
            refine_rel_energy: 0.05,
            max_knots: 32,
            ..AtlasConfig::default()
        },
        energy: EnergyAtlasConfig {
            growth: 1.5,
            max_knots: 12,
            bisect_iters: 12,
            ..EnergyAtlasConfig::default()
        },
    }
}

fn main() {
    let mut b = Bencher::new();

    let build_start = Instant::now();
    let registry = FleetRegistry::new();
    let mut combos: Vec<(String, String)> = Vec::new();
    for p in PLATFORMS {
        for w in WORKLOADS {
            let entry = FleetEntry::build(p, w, &bench_cfg()).unwrap();
            println!(
                "entry {p}/{w}: {} deadline + {} energy knots (floor {:.1} ms / {:.1} uJ)",
                entry.atlas.len(),
                entry.energy.len(),
                entry.atlas.floor().as_ms(),
                entry.energy.floor().as_uj(),
            );
            registry.publish(entry);
            combos.push((p.to_string(), w.to_string()));
        }
    }
    let build_ms = build_start.elapsed().as_secs_f64() * 1e3;
    println!("library: {} entries built in {build_ms:.0} ms\n", registry.len());

    // Request-shaped probes: rotate across every entry and a spread of
    // demands so the measurement covers the whole routing surface.
    let probes: Vec<(String, String, Demand)> = combos
        .iter()
        .flat_map(|(p, w)| {
            let entry = registry.resolve_named(p, w).unwrap().entry;
            let d_floor = entry.atlas.floor();
            let e_floor = entry.energy.floor();
            (0..8).map(move |i| {
                let demand = if i % 2 == 0 {
                    Demand::Deadline(d_floor * (1.5 + i as f64))
                } else {
                    Demand::EnergyBudget(e_floor * (1.2 + i as f64 * 0.7))
                };
                (p.clone(), w.clone(), demand)
            })
        })
        .collect();

    // Baseline: the raw single-atlas binary search the fleet path wraps.
    let single = registry
        .resolve_named(&combos[0].0, &combos[0].1)
        .unwrap()
        .entry;
    let single_floor = single.atlas.floor();
    let idx = Cell::new(0usize);
    let raw = b
        .bench("fleet/raw single-atlas lookup", || {
            let i = idx.get();
            idx.set(i + 1);
            let d = single_floor * (1.5 + (i % 8) as f64);
            single.atlas.lookup(d).unwrap().schedule.decisions.len()
        })
        .mean;

    let idx = Cell::new(0usize);
    let routed = b
        .bench("fleet/registry route + lookup", || {
            let i = idx.get();
            idx.set(i + 1);
            let (p, w, demand) = &probes[i % probes.len()];
            let entry = registry.resolve_named(p, w).unwrap().entry;
            match demand {
                Demand::Deadline(d) => entry.atlas.lookup(*d).unwrap().schedule.decisions.len(),
                Demand::EnergyBudget(e) => {
                    entry.energy.lookup(*e).unwrap().schedule.decisions.len()
                }
            }
        })
        .mean;

    // Hot-swap latency: republish a clone of an existing entry (an atomic
    // Arc swap plus an epoch bump — the cost a live rebuild pays at the
    // moment of cutover, excluding the rebuild itself).
    let template = registry
        .resolve_named(&combos[0].0, &combos[0].1)
        .unwrap()
        .entry;
    let publish = b
        .bench("fleet/hot-swap publish", || {
            registry.publish((*template).clone())
        })
        .mean;

    let overhead = routed.as_secs_f64() / raw.as_secs_f64().max(1e-12);
    println!(
        "\nrouting: raw {:.0} ns, routed {:.0} ns ({overhead:.1}x), publish {:.2} us",
        raw.as_secs_f64() * 1e9,
        routed.as_secs_f64() * 1e9,
        publish.as_secs_f64() * 1e6,
    );
    // The routed path must stay interconnect-grade cheap: far below a
    // millisecond even on a loaded CI box.
    assert!(
        routed.as_secs_f64() < 1e-3,
        "fleet routing took {:.3} ms",
        routed.as_secs_f64() * 1e3
    );

    let out = json_obj! {
        "entries" => registry.len(),
        "library_build_ms" => build_ms,
        "raw_lookup_ns" => raw.as_secs_f64() * 1e9,
        "routed_lookup_ns" => routed.as_secs_f64() * 1e9,
        "routing_overhead_x" => overhead,
        "hot_swap_publish_us" => publish.as_secs_f64() * 1e6,
        "final_epoch" => registry.epoch(),
    };
    std::fs::write("BENCH_fleet.json", out.to_pretty()).expect("write BENCH_fleet.json");
    println!("\nwrote BENCH_fleet.json");

    b.finish("fleet_lookup");
}
