//! Bench: regenerate Fig 6 (per-kernel decision snapshot + assignment
//! histogram) and time schedule generation across the three deadlines.

use medea::exp::{fig6, ExpContext};
use medea::util::bench::Bencher;
use medea::util::units::Time;

fn main() {
    let ctx = ExpContext::paper();
    let mut b = Bencher::new();
    for ms in ExpContext::DEADLINES_MS {
        b.bench(&format!("medea/schedule@{ms:.0}ms"), || {
            ctx.medea()
                .schedule(&ctx.workload, Time::from_ms(ms))
                .unwrap()
        });
    }
    println!("\n{}", fig6::run(&ctx, 2, 12).to_text());
    println!("{}", fig6::histogram(&ctx).to_text());
    b.finish("fig6_schedule");
}
