//! Bench: cross-shard work stealing vs tail latency under a skewed burst.
//!
//! The scenario stealing exists for: one shard's worker is stuck
//! mid-dispatch on a slow lax request (the "plug") while urgent requests
//! land in the queue behind it and the sibling worker idles. Without
//! stealing the urgent tail is served serially by the stuck worker; with
//! stealing the idle sibling lifts EDF-contiguous groups from the loaded
//! shard's queue head, so the two workers share the rescue.
//!
//! Both runs drive the identical pinned-submission burst (everything lands
//! on shard 0 via `ServePool::submit_pinned`, shard 1 idle) through pools
//! that differ only in [`StealConfig`]:
//!
//! * **no-steal** — jobs stay on the shard they were dispatched to;
//! * **steal**   — idle workers rescue the backlog (default policy).
//!
//! Acceptance bar: urgent-request p50 and p99 latency with stealing
//! enabled stay within 10% of the no-steal baseline (the expected signal
//! is a ~2x win; the headroom absorbs runner noise in a two-run wall-clock
//! comparison), with at least one steal recorded and zero deadline misses
//! in either run. The event-driven steal notifier must also deliver at
//! least one wake with p99 delivery latency under the retired 200 us poll
//! floor (`wakeup_p99_us`). Results are printed and written to
//! `BENCH_steal.json`.
//!
//! `cargo bench --bench steal_tail_latency` (set MEDEA_BENCH_FAST=1 to trim).

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::json_obj;
use medea::serve::{
    AtlasConfig, PoolConfig, ScheduleAtlas, ServeMetrics, ServePool, StealConfig, Ticket,
};
use medea::util::stats::percentile;
use std::path::PathBuf;
use std::time::Duration;

struct SkewResult {
    /// Urgent-request host latencies (µs), across all rounds.
    urgent_us: Vec<f64>,
    metrics: ServeMetrics,
    /// p99 of steal-wakeup delivery latency (µs): posted-wake to woken
    /// thief, across every event-driven wake the run delivered.
    wakeup_p99_us: f64,
    wakeups: u64,
    spurious_wakeups: u64,
}

/// One skewed burst per round: a lax plug pinned to shard 0, a beat for
/// worker 0 to go heads-down on it, then the urgent burst pinned behind it.
fn run_skewed(
    atlas: &ScheduleAtlas,
    steal: StealConfig,
    rounds: usize,
    urgent_per_round: usize,
) -> SkewResult {
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 2,
            queue_capacity: 1024,
            artifact_dir: PathBuf::from("/nonexistent-artifacts"),
            steal,
            ..PoolConfig::default()
        },
        atlas.clone(),
    )
    .expect("start pool");
    let plug_deadline = atlas.floor() * 7.9;
    // Tight enough that the batch-makespan check keeps urgent dispatches
    // small (mostly solo), so the rescue is genuinely serial work.
    let urgent_deadline = atlas.floor() * 1.5;
    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let mut urgent_us = Vec::with_capacity(rounds * urgent_per_round);

    for _ in 0..rounds {
        let plug = pool
            .submit_pinned(0, gen.next_window(), plug_deadline)
            .expect("admit plug");
        // Let worker 0 pop the plug so the urgent burst queues behind an
        // in-flight dispatch rather than racing it.
        std::thread::sleep(Duration::from_micros(300));
        let urgent: Vec<Ticket> = (0..urgent_per_round)
            .map(|_| {
                pool.submit_pinned(0, gen.next_window(), urgent_deadline)
                    .expect("admit urgent")
            })
            .collect();
        for t in urgent {
            let out = t.wait().expect("serve urgent");
            assert!(out.sim.deadline_met, "urgent deadline violated");
            urgent_us.push(out.host_latency.as_secs_f64() * 1e6);
        }
        let out = plug.wait().expect("serve plug");
        assert!(out.sim.deadline_met, "plug deadline violated");
    }

    let totals = pool.telemetry().snapshot().totals();
    let metrics = pool.shutdown();
    assert_eq!(
        metrics.aggregate.requests as usize,
        rounds * (urgent_per_round + 1)
    );
    assert_eq!(metrics.aggregate.deadline_misses, 0, "no run may miss deadlines");
    SkewResult {
        urgent_us,
        metrics,
        wakeup_p99_us: totals.wake.percentile(99.0) as f64 / 1e3,
        wakeups: totals.wake.count(),
        spurious_wakeups: totals.spurious_wakeups,
    }
}

fn main() {
    let fast = std::env::var("MEDEA_BENCH_FAST").is_ok();
    let rounds = if fast { 15 } else { 40 };
    let urgent_per_round = 16;

    let ctx = ExpContext::paper();
    let atlas = ScheduleAtlas::build(
        &ctx.medea(),
        &ctx.workload,
        &AtlasConfig {
            relax_factor: 8.0,
            growth: 1.4,
            refine_rel_energy: 0.02,
            max_knots: 48,
            ..AtlasConfig::default()
        },
    )
    .expect("atlas build");
    println!(
        "atlas: {} knots, floor {:.1} ms; skewed burst: {} rounds x (1 plug + {} urgent), all pinned to shard 0 of 2\n",
        atlas.len(),
        atlas.floor().as_ms(),
        rounds,
        urgent_per_round
    );

    let nosteal = run_skewed(&atlas, StealConfig::disabled(), rounds, urgent_per_round);
    let ns_p50 = percentile(&nosteal.urgent_us, 50.0);
    let ns_p99 = percentile(&nosteal.urgent_us, 99.0);
    println!(
        "no-steal: urgent p50 {ns_p50:>8.1} us  p99 {ns_p99:>8.1} us  {}",
        nosteal.metrics.summary()
    );

    let stealing = run_skewed(&atlas, StealConfig::default(), rounds, urgent_per_round);
    let st_p50 = percentile(&stealing.urgent_us, 50.0);
    let st_p99 = percentile(&stealing.urgent_us, 99.0);
    println!(
        "steal:    urgent p50 {st_p50:>8.1} us  p99 {st_p99:>8.1} us  {}",
        stealing.metrics.summary()
    );

    let speedup = ns_p99 / st_p99.max(1e-9);
    println!("\nstealing vs pinned tail: {speedup:.2}x lower urgent p99");
    println!(
        "steal wakeups: {} delivered, p99 {:.1} us ({} spurious)",
        stealing.wakeups, stealing.wakeup_p99_us, stealing.spurious_wakeups
    );
    assert!(
        stealing.metrics.steals() > 0,
        "skewed burst triggered no steals — the idle sibling never rescued the loaded shard"
    );
    assert!(
        stealing.wakeups >= 1,
        "steal run delivered no event-driven wakeups — the backlog notifier never fired"
    );
    // The retired polling loop rediscovered backlog only at the 200 us poll
    // cadence; event-driven wakes must beat that floor outright.
    assert!(
        stealing.wakeup_p99_us < 200.0,
        "steal wakeup p99 must beat the old 200 us poll floor: {:.1} us",
        stealing.wakeup_p99_us
    );
    assert_eq!(nosteal.metrics.steals(), 0, "no-steal run must not steal");
    // The structural win is ~2x (two workers share a rescue one worker did
    // alone), but both gates carry 10% headroom: they are relative
    // wall-clock comparisons between two runs on a possibly shared runner,
    // and a scheduler stall landing on one run's samples must not fail CI
    // when the signal itself is a multiple, not a margin.
    assert!(
        st_p50 <= ns_p50 * 1.10,
        "urgent p50 with stealing must stay within 10% of the no-steal baseline \
         (the expected signal is a ~2x win): {st_p50:.1} us vs {ns_p50:.1} us"
    );
    assert!(
        st_p99 <= ns_p99 * 1.10,
        "urgent p99 with stealing must stay within 10% of the no-steal baseline \
         (the expected signal is a ~2x win): {st_p99:.1} us vs {ns_p99:.1} us"
    );

    let out = json_obj! {
        "rounds" => rounds,
        "urgent_per_round" => urgent_per_round,
        "atlas_knots" => atlas.len(),
        "no_steal" => json_obj! {
            "urgent_p50_us" => ns_p50,
            "urgent_p99_us" => ns_p99,
            "steals" => nosteal.metrics.steals(),
        },
        "steal" => json_obj! {
            "urgent_p50_us" => st_p50,
            "urgent_p99_us" => st_p99,
            "steals" => stealing.metrics.steals(),
            "stolen_requests" => stealing.metrics.stolen_requests(),
            "wakeup_p99_us" => stealing.wakeup_p99_us,
            "wakeups" => stealing.wakeups,
            "spurious_wakeups" => stealing.spurious_wakeups,
        },
        "p99_speedup" => speedup,
    };
    std::fs::write("BENCH_steal.json", out.to_pretty()).expect("write BENCH_steal.json");
    println!("\nwrote BENCH_steal.json");
}
