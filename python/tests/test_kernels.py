"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes; values come from seeded jax PRNG per example.
This is the core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gelu_pwl, layernorm, taylor_softmax, tiled_matmul
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, *shape, scale=3.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---- tiled matmul ---------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 96),
    n=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, m, k)
    b = rand(seed + 1, k, n)
    got = tiled_matmul(a, b)
    want = ref.matmul(a, b)
    # Accumulation-order differences across tiles: tolerance scaled to f32.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (97, 128, 32),   # TSD per-head QKV projection
        (97, 32, 97),    # QK^T
        (97, 97, 32),    # AV
        (97, 128, 128),  # output projection
        (97, 128, 256),  # FF1 (the kernel that must tile in 64 KiB)
        (97, 256, 128),  # FF2
        (96, 80, 128),   # patch embedding
        (1, 128, 2),     # classifier head
    ],
)
def test_matmul_tsd_shapes(m, k, n):
    a = rand(m * 1000 + n, m, k)
    b = rand(k * 7 + 1, k, n)
    np.testing.assert_allclose(tiled_matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("tm,tn", [(8, 16), (32, 128), (97, 97), (128, 256)])
def test_matmul_tile_size_invariance(tm, tn):
    """Any legal tile size must give the same numbers."""
    a = rand(11, 97, 64)
    b = rand(12, 64, 96)
    base = tiled_matmul(a, b)
    np.testing.assert_allclose(tiled_matmul(a, b, tm=tm, tn=tn), base, rtol=1e-5, atol=1e-4)


# ---- Taylor softmax -------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 128), cols=st.integers(2, 130), seed=st.integers(0, 2**31 - 1))
def test_taylor_softmax_matches_ref(rows, cols, seed):
    x = rand(seed, rows, cols, scale=5.0)
    got = taylor_softmax(x)
    want = ref.taylor_softmax(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_taylor_softmax_is_a_distribution():
    x = rand(3, 97, 97, scale=8.0)
    y = np.asarray(taylor_softmax(x))
    assert (y > 0).all(), "Taylor polynomial of shifted rows must stay positive"
    np.testing.assert_allclose(y.sum(axis=-1), 1.0, rtol=1e-5)


def test_taylor_softmax_close_to_true_softmax_for_small_logits():
    # For |z| small the Taylor gate approximates exp well.
    x = 0.3 * rand(4, 16, 16, scale=1.0)
    approx = np.asarray(taylor_softmax(x))
    true = np.asarray(jax.nn.softmax(x, axis=-1))
    # 2nd-order Taylor of exp on [-2, 0]-ish shifted logits: a few percent.
    assert np.abs(approx - true).max() < 0.06


# ---- PWL GeLU -------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 128), cols=st.integers(1, 260), seed=st.integers(0, 2**31 - 1))
def test_gelu_pwl_matches_ref(rows, cols, seed):
    x = rand(seed, rows, cols, scale=4.0)
    np.testing.assert_allclose(gelu_pwl(x), ref.gelu_pwl(x), rtol=1e-6, atol=1e-6)


def test_gelu_pwl_segments():
    x = jnp.array([[-10.0, -1.7630, 0.0, 1.7630, 10.0]])
    y = np.asarray(gelu_pwl(x))[0]
    assert y[0] == 0.0  # dead segment
    assert abs(y[2]) < 1e-7  # x·g(0) = 0
    np.testing.assert_allclose(y[4], 10.0, rtol=1e-6)  # identity segment


def test_gelu_pwl_tracks_true_gelu():
    x = jnp.linspace(-4.0, 4.0, 201).reshape(1, -1)
    approx = np.asarray(gelu_pwl(x))[0]
    true = np.asarray(jax.nn.gelu(x, approximate=False))[0]
    assert np.abs(approx - true).max() < 0.3  # ULP-grade approximation


# ---- layer norm -----------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 128), cols=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(rows, cols, seed):
    x = rand(seed, rows, cols, scale=6.0)
    np.testing.assert_allclose(layernorm(x), ref.layernorm(x), rtol=1e-4, atol=1e-5)


def test_layernorm_output_statistics():
    x = rand(9, 64, 128, scale=10.0)
    y = np.asarray(layernorm(x))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-3)


# ---- fft frontend oracle sanity ------------------------------------------


def test_fft_mag_basic():
    # A pure tone must put its energy in the right bin.
    n = 256
    t = np.arange(n) / n
    x = jnp.asarray(np.sin(2 * np.pi * 8 * t), dtype=jnp.float32).reshape(1, -1)
    mag = np.asarray(ref.fft_mag(x))
    assert mag[0].argmax() == 8
    # Truncation keeps the leading bins.
    mag80 = np.asarray(ref.fft_mag(x, n_bins=80))
    assert mag80.shape == (1, 80)
    np.testing.assert_allclose(mag80[0], mag[0][:80], rtol=1e-6)
