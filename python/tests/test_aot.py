"""AOT lowering contract: HLO text emission must stay compatible with the
rust loader (HloModule text, return_tuple semantics, stable shapes)."""

import jax
import jax.numpy as jnp
import pytest

from compile.aot import spec, to_hlo_text
from compile.kernels import taylor_softmax, tiled_matmul


def lower(fn, *specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


def test_hlo_text_header_and_tuple_root():
    text = lower(lambda a, b: (tiled_matmul(a, b),), spec((8, 16)), spec((16, 4)))
    # The rust side requires parseable HLO text…
    assert text.startswith("HloModule")
    # …and a tuple root (aot.py lowers with return_tuple=True).
    assert "ROOT" in text
    root_line = next(l for l in text.splitlines() if "ROOT" in l and "tuple" in l)
    assert "(f32[8,4]" in root_line.replace(" ", "") or "f32[8,4]" in root_line


def test_hlo_contains_no_custom_calls():
    # interpret=True Pallas must lower to plain HLO ops: a Mosaic
    # custom-call would be unloadable by the CPU PJRT client.
    for fn, specs in [
        (lambda a, b: (tiled_matmul(a, b),), (spec((8, 16)), spec((16, 4)))),
        (lambda x: (taylor_softmax(x),), (spec((9, 7)),)),
    ]:
        text = lower(fn, *specs)
        assert "custom-call" not in text, "Mosaic custom-call leaked into HLO"


def test_lowering_is_deterministic():
    a = lower(lambda x: (taylor_softmax(x),), spec((9, 7)))
    b = lower(lambda x: (taylor_softmax(x),), spec((9, 7)))
    assert a == b


def test_shape_mismatch_rejected_at_lowering():
    with pytest.raises(Exception):
        lower(lambda a, b: (tiled_matmul(a, b),), spec((8, 16)), spec((15, 4)))


def test_f32_only_artifacts():
    # The rust runtime reads f32 literals; guard the contract.
    text = lower(lambda a, b: (tiled_matmul(a, b),), spec((4, 4)), spec((4, 4)))
    assert "f64" not in text


def test_spec_helper():
    s = spec((3, 5))
    assert s.shape == (3, 5)
    assert s.dtype == jnp.float32
