"""L2 correctness: the Pallas-kernel model vs its pure-jnp twin, shapes,
determinism, and the AOT manifest contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    TsdConfig,
    encoder_block,
    frontend,
    init_weights,
    tsd_core_forward,
    tsd_forward,
    tsd_forward_ref,
)


@pytest.fixture(scope="module")
def cfg():
    return TsdConfig()


@pytest.fixture(scope="module")
def weights(cfg):
    return init_weights(cfg, seed=0)


@pytest.fixture(scope="module")
def eeg(cfg):
    key = jax.random.PRNGKey(42)
    return 50e-6 * jax.random.normal(key, (cfg.channels, cfg.window_samples), jnp.float32)


def test_config_mirrors_rust_ir(cfg):
    # Must match TsdParams::default() in rust/src/ir/tsd.rs.
    assert cfg.patches == 96
    assert cfg.seq == 97
    assert cfg.d_model == 128
    assert cfg.heads == 4
    assert cfg.d_head == 32
    assert cfg.d_ff == 256
    assert cfg.blocks == 4
    assert cfg.n_classes == 2


def test_frontend_shape_and_range(cfg, eeg):
    feats = frontend(cfg, eeg)
    assert feats.shape == (cfg.patches, cfg.patch_dim)
    f = np.asarray(feats)
    assert np.isfinite(f).all()
    assert f.max() <= 1.0 + 1e-6 and f.min() >= 0.0


def test_encoder_block_shape(cfg, weights):
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq, cfg.d_model), jnp.float32)
    y = encoder_block(cfg, weights, 0, x)
    assert y.shape == (cfg.seq, cfg.d_model)
    assert np.isfinite(np.asarray(y)).all()


def test_full_model_matches_ref_twin(cfg, weights, eeg):
    """The core L2 signal: Pallas-kernel model ≡ pure-jnp model."""
    got = np.asarray(tsd_forward(cfg, weights, eeg))
    want = np.asarray(tsd_forward_ref(cfg, weights, eeg))
    assert got.shape == (cfg.n_classes,)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_forward_is_deterministic(cfg, weights, eeg):
    a = np.asarray(tsd_forward(cfg, weights, eeg))
    b = np.asarray(tsd_forward(cfg, weights, eeg))
    np.testing.assert_array_equal(a, b)


def test_weights_deterministic_per_seed(cfg):
    a = init_weights(cfg, seed=7)
    b = init_weights(cfg, seed=7)
    c = init_weights(cfg, seed=8)
    np.testing.assert_array_equal(np.asarray(a["embed"]), np.asarray(b["embed"]))
    assert np.abs(np.asarray(a["embed"]) - np.asarray(c["embed"])).max() > 1e-3


def test_core_forward_consumes_features(cfg, weights, eeg):
    feats = frontend(cfg, eeg)
    logits = tsd_core_forward(cfg, weights, feats)
    full = tsd_forward(cfg, weights, eeg)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), rtol=1e-5, atol=1e-6)


def test_weight_inventory_matches_fig4(cfg, weights):
    names = set(weights.tensors)
    expected = {"embed", "class_token", "classifier"}
    for b in range(cfg.blocks):
        expected |= {f"b{b}.proj", f"b{b}.ff1", f"b{b}.ff2"}
        for h in range(cfg.heads):
            expected |= {f"b{b}.h{h}.wq", f"b{b}.h{h}.wk", f"b{b}.h{h}.wv"}
    assert names == expected


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_contract():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"tsd_full", "tsd_core", "k_softmax", "k_norm", "k_gelu"} <= names
    for a in manifest["artifacts"]:
        path = os.path.join(ARTIFACTS, a["file"])
        assert os.path.exists(path), a["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), f"{a['file']} is not HLO text"
        assert len(a["inputs"]) >= 1
