"""Build-time compile path: JAX model + Pallas kernels -> HLO text artifacts.

Never imported at runtime; the rust coordinator only consumes artifacts/.
"""
