"""AOT compile path: lower the L2 model + L1 kernels to HLO *text*.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  * ``tsd_full.hlo.txt``      — whole model, weights baked in:
                                 (channels, samples) f32 → (n_classes,) f32
  * ``tsd_core.hlo.txt``      — transformer core: (patches, patch_dim) → logits
  * ``k_<name>.hlo.txt``      — per-kernel executables (generic weights as
                                 runtime inputs) for the rust coordinator's
                                 kernel-level dispatch
  * ``manifest.json``         — shapes/dtypes of every artifact

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from ``python/``).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import gelu_pwl, layernorm, taylor_softmax, tiled_matmul
from .model import TsdConfig, init_weights, tsd_core_forward, tsd_forward


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_to_file(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return text


def build_artifacts(out_dir: str, seed: int = 0) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    cfg = TsdConfig()
    w = init_weights(cfg, seed=seed)
    manifest = {"seed": seed, "config": cfg.__dict__, "artifacts": []}

    def emit(name, fn, arg_specs, outputs_doc):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        lower_to_file(fn, arg_specs, path)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in arg_specs],
                "outputs": outputs_doc,
            }
        )
        print(f"  wrote {path}")

    # Full model (weights baked in via closure).
    emit(
        "tsd_full",
        lambda eeg: (tsd_forward(cfg, w, eeg),),
        [spec((cfg.channels, cfg.window_samples))],
        [{"shape": [cfg.n_classes], "dtype": "float32"}],
    )
    # Transformer core (features in).
    emit(
        "tsd_core",
        lambda feats: (tsd_core_forward(cfg, w, feats),),
        [spec((cfg.patches, cfg.patch_dim))],
        [{"shape": [cfg.n_classes], "dtype": "float32"}],
    )

    # Generic per-kernel executables for kernel-level dispatch from rust.
    seq, dm, dh, dff = cfg.seq, cfg.d_model, cfg.d_head, cfg.d_ff
    mm_shapes = {
        "mm_qkv": (seq, dm, dh),
        "mm_qk": (seq, dh, seq),
        "mm_av": (seq, seq, dh),
        "mm_proj": (seq, dm, dm),
        "mm_ff1": (seq, dm, dff),
        "mm_ff2": (seq, dff, dm),
        "mm_embed": (cfg.patches, cfg.patch_dim, dm),
        "mm_class": (1, dm, cfg.n_classes),
    }
    for name, (m, k, n) in mm_shapes.items():
        emit(
            f"k_{name}",
            lambda a, b: (tiled_matmul(a, b),),
            [spec((m, k)), spec((k, n))],
            [{"shape": [m, n], "dtype": "float32"}],
        )
    emit(
        "k_softmax",
        lambda x: (taylor_softmax(x),),
        [spec((seq, seq))],
        [{"shape": [seq, seq], "dtype": "float32"}],
    )
    emit(
        "k_gelu",
        lambda x: (gelu_pwl(x),),
        [spec((seq, dff))],
        [{"shape": [seq, dff], "dtype": "float32"}],
    )
    emit(
        "k_norm",
        lambda x: (layernorm(x),),
        [spec((seq, dm))],
        [{"shape": [seq, dm], "dtype": "float32"}],
    )
    emit(
        "k_add",
        lambda a, b: (a + b,),
        [spec((seq, dm)), spec((seq, dm))],
        [{"shape": [seq, dm], "dtype": "float32"}],
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build_artifacts(args.out_dir, seed=args.seed)


if __name__ == "__main__":
    main()
