"""Layer-2: the TSD (Transformer for Seizure Detection) model in JAX.

Mirrors the kernel decomposition of the paper's Fig 4 (and the Rust IR in
``rust/src/ir/tsd.rs``): FFT-magnitude frontend → patch embedding + class
token → 4 transformer encoder blocks (per-head MHSA with Taylor softmax,
PWL-GeLU FFN) → classifier head. All linear algebra goes through the L1
Pallas kernels so they lower into the same HLO module at AOT time.

Weights are generated deterministically from a seed (the TUSZ-trained
weights are not reproducible here — see DESIGN.md substitution ledger);
numerical correctness is established against the pure-jnp reference, not
against clinical accuracy.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import gelu_pwl, layernorm, taylor_softmax, tiled_matmul
from .kernels import ref


@dataclass(frozen=True)
class TsdConfig:
    """Mirrors ``TsdParams`` in the Rust IR (rust/src/ir/tsd.rs)."""

    channels: int = 16
    n_fft: int = 256
    segments_per_channel: int = 6
    patch_dim: int = 80
    d_model: int = 128
    blocks: int = 4
    heads: int = 4
    d_ff: int = 256
    n_classes: int = 2

    @property
    def patches(self) -> int:
        return self.channels * self.segments_per_channel  # 96

    @property
    def seq(self) -> int:
        return self.patches + 1  # + class token

    @property
    def d_head(self) -> int:
        return self.d_model // self.heads

    @property
    def window_samples(self) -> int:
        return self.segments_per_channel * self.n_fft  # per channel


@dataclass
class TsdWeights:
    """All model parameters as a flat dict of jnp arrays."""

    tensors: dict = field(default_factory=dict)

    def __getitem__(self, k):
        return self.tensors[k]


def init_weights(cfg: TsdConfig, seed: int = 0) -> TsdWeights:
    """Deterministic synthetic weights, scaled for stable activations."""
    key = jax.random.PRNGKey(seed)
    t = {}

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def dense(name, fan_in, fan_out):
        t[name] = jax.random.normal(nxt(), (fan_in, fan_out), jnp.float32) / jnp.sqrt(
            float(fan_in)
        )

    dense("embed", cfg.patch_dim, cfg.d_model)
    t["class_token"] = jax.random.normal(nxt(), (1, cfg.d_model), jnp.float32) * 0.02
    for b in range(cfg.blocks):
        for h in range(cfg.heads):
            dense(f"b{b}.h{h}.wq", cfg.d_model, cfg.d_head)
            dense(f"b{b}.h{h}.wk", cfg.d_model, cfg.d_head)
            dense(f"b{b}.h{h}.wv", cfg.d_model, cfg.d_head)
        dense(f"b{b}.proj", cfg.d_model, cfg.d_model)
        dense(f"b{b}.ff1", cfg.d_model, cfg.d_ff)
        dense(f"b{b}.ff2", cfg.d_ff, cfg.d_model)
    dense("classifier", cfg.d_model, cfg.n_classes)
    return TsdWeights(t)


def frontend(cfg: TsdConfig, eeg):
    """FFT-magnitude frontend (§4.3: no log).

    ``eeg``: (channels, segments·n_fft) → (patches, patch_dim) features.
    Stays in plain jnp: the rFFT is a host-CPU kernel in Λ_op, not a Pallas
    target.
    """
    segs = eeg.reshape(cfg.channels * cfg.segments_per_channel, cfg.n_fft)
    mag = ref.fft_mag(segs, n_bins=cfg.patch_dim)
    # Normalize per patch to keep the synthetic-weight transformer in range.
    mag = mag / (jnp.max(mag, axis=-1, keepdims=True) + 1e-6)
    return mag


def encoder_block(cfg: TsdConfig, w: TsdWeights, b: int, x):
    """One encoder block, decomposed per Fig 4 (per-head chains)."""
    seq = cfg.seq
    scale = 1.0 / jnp.sqrt(float(cfg.d_head))

    h_in = layernorm(x)  # N
    heads = []
    for h in range(cfg.heads):
        q = tiled_matmul(h_in, w[f"b{b}.h{h}.wq"])  # MM
        k = tiled_matmul(h_in, w[f"b{b}.h{h}.wk"])  # MM
        v = tiled_matmul(h_in, w[f"b{b}.h{h}.wv"])  # MM
        kt = k.T  # T
        s = tiled_matmul(q, kt)  # MM (QK^T)
        s = s * scale  # S
        a = taylor_softmax(s)  # SM
        heads.append(tiled_matmul(a, v))  # MM (AV)
    concat = jnp.concatenate(heads, axis=-1)
    proj = tiled_matmul(concat, w[f"b{b}.proj"])  # MM
    x = x + proj  # A

    f_in = layernorm(x)  # N
    f1 = tiled_matmul(f_in, w[f"b{b}.ff1"])  # MM
    g = gelu_pwl(f1)  # G
    f2 = tiled_matmul(g, w[f"b{b}.ff2"])  # MM
    x = x + f2  # A
    assert x.shape == (seq, cfg.d_model)
    return x


def tsd_forward(cfg: TsdConfig, w: TsdWeights, eeg):
    """Full model: EEG window (channels, samples) → class logits."""
    feats = frontend(cfg, eeg)  # (patches, patch_dim)
    x = tiled_matmul(feats, w["embed"])  # MM (patch embedding)
    x = jnp.concatenate([w["class_token"], x], axis=0)  # CC
    for b in range(cfg.blocks):
        x = encoder_block(cfg, w, b, x)
    cls = layernorm(x[:1, :])  # final N on the class token
    logits = tiled_matmul(cls, w["classifier"])  # MM
    return logits[0]


def tsd_core_forward(cfg: TsdConfig, w: TsdWeights, feats):
    """Transformer core only (features in): the §4.3 comparative workload."""
    x = tiled_matmul(feats, w["embed"])
    x = jnp.concatenate([w["class_token"], x], axis=0)
    for b in range(cfg.blocks):
        x = encoder_block(cfg, w, b, x)
    cls = layernorm(x[:1, :])
    return tiled_matmul(cls, w["classifier"])[0]


# ---- pure-jnp reference twin (oracle for the whole model) -----------------


def tsd_forward_ref(cfg: TsdConfig, w: TsdWeights, eeg):
    """Same model built only from ref.py ops — the L2 correctness oracle."""
    feats = frontend(cfg, eeg)
    x = ref.matmul(feats, w["embed"])
    x = jnp.concatenate([w["class_token"], x], axis=0)
    scale = 1.0 / jnp.sqrt(float(cfg.d_head))
    for b in range(cfg.blocks):
        h_in = ref.layernorm(x)
        heads = []
        for h in range(cfg.heads):
            q = ref.matmul(h_in, w[f"b{b}.h{h}.wq"])
            k = ref.matmul(h_in, w[f"b{b}.h{h}.wk"])
            v = ref.matmul(h_in, w[f"b{b}.h{h}.wv"])
            s = ref.matmul(q, k.T) * scale
            heads.append(ref.matmul(ref.taylor_softmax(s), v))
        x = x + ref.matmul(jnp.concatenate(heads, axis=-1), w[f"b{b}.proj"])
        f_in = ref.layernorm(x)
        x = x + ref.matmul(ref.gelu_pwl(ref.matmul(f_in, w[f"b{b}.ff1"])), w[f"b{b}.ff2"])
    cls = ref.layernorm(x[:1, :])
    return ref.matmul(cls, w["classifier"])[0]
