"""Row-wise layer-norm Pallas kernel (L1, no affine parameters).

Matches ``ref.layernorm`` exactly (same eps, same op order). Whole rows per
block — the reduction axis is never split, as in the L3 row-wise tiling
model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, o_ref, *, eps):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mean) / jnp.sqrt(var + eps)


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x, eps: float = 1e-5):
    rows, cols = x.shape
    block_rows = rows
    for candidate in (64, 32, 16, 8, 4, 2, 1):
        if rows % candidate == 0 and candidate * cols * 4 * 2 <= 64 * 1024:
            block_rows = candidate
            break
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)
