"""Piece-wise-linear GeLU Pallas kernel (L1).

The paper's §4.3 modification: the erf gate becomes the 3-segment PWL gate
``clip((1.702·x + 3)/6, 0, 1)``. Matches ``ref.gelu_pwl`` exactly.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu_pwl_kernel(x_ref, o_ref):
    x = x_ref[...]
    gate = jnp.clip((1.702 * x + 3.0) / 6.0, 0.0, 1.0)
    o_ref[...] = x * gate


@jax.jit
def gelu_pwl(x):
    """Element-wise PWL GeLU over a 2-D array, tiled by row blocks."""
    rows, cols = x.shape
    block_rows = rows
    for candidate in (64, 32, 16, 8, 4, 2, 1):
        if rows % candidate == 0 and candidate * cols * 4 * 2 <= 64 * 1024:
            block_rows = candidate
            break
    return pl.pallas_call(
        _gelu_pwl_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)
