"""Tiled matmul Pallas kernel (L1).

The grid iterates (m, n) tiles with the full K dimension resident per tile —
the same strip/panel schedule the L3 tile planner models for the
HEEPtimize accelerators. Tile sizes are chosen so one tile's working set
(A-strip + B-panel + f32 accumulator) fits a 64 KiB "VMEM-as-LM" budget.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): on a real TPU
this BlockSpec expresses the HBM→VMEM schedule and the MXU consumes the
tiles; under ``interpret=True`` it lowers to plain HLO the CPU PJRT client
can execute, which is the correctness path used here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``preferred``."""
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def tiled_matmul(a, b, tm: int = 32, tn: int = 128):
    """C = A @ B with (tm × tn) output tiles, full-K panels.

    Shapes need not divide the tile sizes: inputs are zero-padded to the
    tile grid and the result is sliced back (zero rows/cols contribute
    nothing to the contraction).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    tm_eff = min(tm, m)
    tn_eff = min(tn, n)
    pad_m = (-m) % tm_eff
    pad_n = (-n) % tn_eff
    a_p = jnp.pad(a, ((0, pad_m), (0, 0))) if pad_m else a
    b_p = jnp.pad(b, ((0, 0), (0, pad_n))) if pad_n else b
    mp, np_ = m + pad_m, n + pad_n

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // tm_eff, np_ // tn_eff),
        in_specs=[
            pl.BlockSpec((tm_eff, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn_eff), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm_eff, tn_eff), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]
