"""Row-wise Taylor-expansion softmax Pallas kernel (L1).

Implements the paper's §4.3 modification: exp replaced by its 3-coefficient
Taylor polynomial ``t(z) = 1 + z + z²/2`` on max-shifted rows, then
row-normalized. Matches ``ref.taylor_softmax`` bit-for-bit in f32 (same
operations, same order).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _taylor_softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    z = x - jnp.max(x, axis=-1, keepdims=True)
    t = 1.0 + z + 0.5 * z * z
    o_ref[...] = t / jnp.sum(t, axis=-1, keepdims=True)


@jax.jit
def taylor_softmax(x):
    """Row-wise Taylor softmax over the last axis of a 2-D array.

    Rows are processed in row-blocks; each block holds whole rows (the
    reduction axis is never split), mirroring the L3 planner's row-wise
    tiling constraint for `norm`/`softmax` kernels.
    """
    rows, cols = x.shape
    # Whole rows per block; pick a row-block that divides `rows`.
    block_rows = rows
    for candidate in (64, 32, 16, 8, 4, 2, 1):
        if rows % candidate == 0 and candidate * cols * 4 <= 64 * 1024:
            block_rows = candidate
            break
    return pl.pallas_call(
        _taylor_softmax_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)
