"""Layer-1 Pallas kernels for the TSD model.

Every kernel is written with ``pl.pallas_call(..., interpret=True)`` so the
lowered HLO contains plain ops executable by the CPU PJRT client (real-TPU
Pallas lowers to Mosaic custom-calls the CPU plugin cannot run). BlockSpecs
tile to a 64 KiB "VMEM-as-LM" budget, mirroring the HEEPtimize local-memory
discipline the L3 tiling planner models.
"""

from .matmul import tiled_matmul
from .softmax_taylor import taylor_softmax
from .gelu_pwl import gelu_pwl
from .layernorm import layernorm

__all__ = ["tiled_matmul", "taylor_softmax", "gelu_pwl", "layernorm"]
