"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These implement the *same approximations* as the paper's modified TSD model
(§4.3): Taylor-expansion softmax, piece-wise-linear GeLU, magnitude-only FFT
frontend — so the Pallas kernels must match them exactly (same formula, same
dtype), not merely approximate float softmax/GeLU.
"""

import jax.numpy as jnp


def matmul(a, b):
    """C = A @ B in float32."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def taylor_softmax(x):
    """Row-wise 3-coefficient Taylor softmax (ConSmax-style, §4.3).

    exp(z) is replaced by its 2nd-order Taylor polynomial around 0,
    t(z) = 1 + z + z²/2, evaluated on max-shifted rows (z ≤ 0 so t(z) ∈
    (0, 1]; the polynomial of a negative argument stays positive since
    1 + z + z²/2 = ((z+1)² + 1)/2 > 0), then row-normalized.
    """
    z = x - jnp.max(x, axis=-1, keepdims=True)
    t = 1.0 + z + 0.5 * z * z
    return t / jnp.sum(t, axis=-1, keepdims=True)


def gelu_pwl(x):
    """Piece-wise-linear GeLU (§4.3): x · hardgate(x).

    The erf gate is replaced by the PWL hard gate
    g(x) = clip((1.702·x + 3) / 6, 0, 1), a ULP-friendly 3-segment
    approximation (g ≡ 0 below ≈ −1.763, linear in between, 1 above ≈ 1.763).
    """
    gate = jnp.clip((1.702 * x + 3.0) / 6.0, 0.0, 1.0)
    return x * gate


def layernorm(x, eps=1e-5):
    """Row-wise layer norm without affine parameters."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps)


def fft_mag(x, n_bins=None):
    """Magnitude of the rFFT over the last axis (no log — the paper's
    modification replaces log-amplitude with plain magnitude)."""
    mag = jnp.abs(jnp.fft.rfft(x, axis=-1))
    if n_bins is not None:
        mag = mag[..., :n_bins]
    return mag
