//! Fleet serving: build a multi-platform atlas library, serve mixed
//! deadline- and energy-budget traffic for two platforms and two workloads
//! through one pool, then hot-swap a rebuilt atlas under live traffic.
//! Runs without AOT artifacts — responses are schedule-only.
//!
//! ```sh
//! cargo run --release --example fleet_serving
//! ```

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::fleet::{
    Demand, EnergyAtlasConfig, FleetConfig, FleetEntry, FleetPool, FleetPoolConfig, FleetRegistry,
};
use medea::serve::AtlasConfig;
use std::sync::Arc;
use std::time::Instant;

fn build_cfg() -> FleetConfig {
    // Coarse sweeps keep the example snappy; `medea fleet build` uses the
    // production defaults.
    FleetConfig {
        atlas: AtlasConfig {
            relax_factor: 8.0,
            growth: 1.5,
            refine_rel_energy: 0.05,
            max_knots: 32,
            ..AtlasConfig::default()
        },
        energy: EnergyAtlasConfig {
            growth: 1.5,
            max_knots: 10,
            bisect_iters: 12,
            ..EnergyAtlasConfig::default()
        },
    }
}

fn main() {
    // 1. Design time: one library entry per (platform preset, workload).
    let registry = Arc::new(FleetRegistry::new());
    let t0 = Instant::now();
    for platform in ["heeptimize", "heeptimize-hp"] {
        for workload in ["tsd-core", "tsd-small"] {
            let entry = FleetEntry::build(platform, workload, &build_cfg()).expect("entry build");
            println!(
                "entry {platform}/{workload}: key {}, {} deadline knots (floor {:.1} ms), \
                 {} energy knots (floor {:.1} uJ)",
                entry.key,
                entry.atlas.len(),
                entry.atlas.floor().as_ms(),
                entry.energy.len(),
                entry.energy.floor().as_uj(),
            );
            registry.publish(entry);
        }
    }
    println!(
        "library: {} entries in {:.0} ms\n",
        registry.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 2. Serve time: one pool, requests tagged with (platform, workload)
    // and carrying either a deadline or an energy cap.
    let pool = FleetPool::start(
        registry.clone(),
        FleetPoolConfig {
            workers: 4,
            ..FleetPoolConfig::default()
        },
    )
    .expect("start pool");

    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let mut tickets = Vec::new();
    for i in 0..24 {
        let platform = if i % 2 == 0 { "heeptimize" } else { "heeptimize-hp" };
        let workload = if i % 4 < 2 { "tsd-core" } else { "tsd-small" };
        let entry = registry.resolve_named(platform, workload).unwrap().entry;
        let demand = if i % 3 == 0 {
            Demand::EnergyBudget(entry.energy.floor() * 1.8)
        } else {
            Demand::Deadline(entry.atlas.floor() * 3.0)
        };
        match pool.submit(platform, workload, gen.next_window(), demand) {
            Ok(t) => tickets.push(t),
            Err(rejection) => println!("request {i:>2}: {rejection}"),
        }
    }

    // 3. Hot swap under traffic: rebuild one entry with a finer sweep and
    // publish it — queued requests finish on the old atlas, new requests
    // resolve the new one. Nothing drains, nothing is rejected.
    let mut finer = build_cfg();
    finer.atlas.growth = 1.2;
    let rebuilt = FleetEntry::build("heeptimize", "tsd-core", &finer).expect("rebuild");
    let knots = rebuilt.atlas.len();
    let epoch = registry.publish(rebuilt);
    println!("\nhot swap: heeptimize/tsd-core now {knots} knots at epoch {epoch}\n");
    let entry = registry.resolve_named("heeptimize", "tsd-core").unwrap().entry;
    for _ in 0..8 {
        tickets.push(
            pool.submit(
                "heeptimize",
                "tsd-core",
                gen.next_window(),
                Demand::Deadline(entry.atlas.floor() * 3.0),
            )
            .expect("post-swap submit"),
        );
    }

    for t in tickets {
        let out = t.wait().expect("serve");
        if out.window_index < 6 || out.window_index >= 24 {
            let demand = match out.demand {
                Demand::Deadline(d) => format!("deadline {:>6.1} ms", d.as_ms()),
                Demand::EnergyBudget(b) => format!("cap {:>8.1} uJ", b.as_uj()),
            };
            println!(
                "request {:>2}: {:>13}/{:<9} epoch {} {} -> sim {:>6.2} ms / {:>7.1} uJ (met={})",
                out.window_index,
                out.platform,
                out.workload,
                out.epoch,
                demand,
                out.sim.active_time.as_ms(),
                out.sim.total_energy().as_uj(),
                out.sim.deadline_met,
            );
        }
    }

    // 4. Cross-worker metrics.
    let metrics = pool.shutdown();
    println!("\n{}", metrics.summary());
}
