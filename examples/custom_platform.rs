//! Bring-your-own platform and DNN: MEDEA is not tied to HEEPtimize or to
//! transformers. This example defines a two-PE wearable SoC (RISC-V host +
//! a single NMC), persists it to JSON, and schedules a small CNN over it —
//! exercising the conv2d path, the loader round-trip, and the deadline
//! sweep on a platform with a different V-F table.
//!
//! ```sh
//! cargo run --release --example custom_platform
//! ```

use medea::ir::builder::small_cnn;
use medea::ir::{DataWidth, KernelType};
use medea::manager::medea::Medea;
use medea::platform::loader::{load_platform, save_platform};
use medea::platform::{
    DmaSpec, OpConstraint, OpConstraints, Pe, PeClass, PeId, PePower, Platform, VfPoint, VfTable,
};
use medea::profile::characterize;
use medea::sim::replay::simulate;
use medea::timing::cycle_model::CycleModel;
use medea::util::units::{Bytes, Power, Time, Voltage};
use std::collections::BTreeMap;

fn wearable_soc() -> Platform {
    let cpu_power = PePower {
        p_stat_ref: Power::from_uw(60.0),
        v_ref: Voltage(0.7),
        leak_exp: 2.6,
        c_eff: 22.0e-12,
        e_fixed: 0.0,
        activity: BTreeMap::new(),
    };
    let nmc_power = PePower {
        p_stat_ref: Power::from_uw(420.0),
        v_ref: Voltage(0.7),
        leak_exp: 1.6,
        c_eff: 10.0e-12,
        e_fixed: 8.0e-12,
        activity: BTreeMap::new(),
    };
    let base = PePower {
        p_stat_ref: Power::from_uw(120.0),
        v_ref: Voltage(0.7),
        leak_exp: 2.0,
        c_eff: 15.0e-12,
        e_fixed: 0.0,
        activity: BTreeMap::new(),
    };

    let mut constraints = OpConstraints::new();
    constraints.allow_all(PeId(0));
    for ty in [
        KernelType::MatMul,
        KernelType::Conv2d,
        KernelType::Add,
        KernelType::Norm,
        KernelType::Scale,
    ] {
        constraints.allow(
            PeId(1),
            ty,
            OpConstraint::with_max_dim(256).widths(&[DataWidth::Int8, DataWidth::Int16]),
        );
    }

    Platform {
        name: "wearable-soc".into(),
        pes: vec![
            Pe {
                id: PeId(0),
                name: "cpu".into(),
                class: PeClass::RiscvCpu,
                lm: None,
                dma: None,
                power: cpu_power,
            },
            Pe {
                id: PeId(1),
                name: "nmc".into(),
                class: PeClass::Nmc,
                lm: Some(Bytes::from_kib(32)),
                dma: Some(DmaSpec {
                    bytes_per_cycle: 1.3,
                    setup_cycles: 100,
                }),
                power: nmc_power,
            },
        ],
        // A two-point V-F table — a cheaper PMU than HEEPtimize's.
        vf: VfTable::new(vec![VfPoint::new(0.55, 90.0), VfPoint::new(0.8, 400.0)]),
        l2: Bytes::from_kib(64),
        sleep_power: Power::from_uw(40.0),
        constraints,
        vf_switch_cycles: 180,
        active_base: base,
    }
}

fn main() {
    // 1. Define + persist + reload the platform (the JSON is the artifact a
    //    hardware team would ship with their characterization data).
    let platform = wearable_soc();
    platform.validate().expect("valid platform");
    let path = std::env::temp_dir().join("wearable_soc.json");
    save_platform(&platform, &path).unwrap();
    let platform = load_platform(&path).unwrap();
    println!("platform `{}` round-tripped via {path:?}", platform.name);

    // 2. Characterize it (the stand-in for this SoC's own FPGA/ASIC data).
    let model = CycleModel::heeptimize(); // same microarchitectural families
    let profiles = characterize(&platform, &model);
    println!(
        "characterized: {} timing points, {} power entries",
        profiles.timing_entry_count(),
        profiles.power_entry_count()
    );

    // 3. A small CNN keyword-spotter-style workload (not a transformer).
    let workload = small_cnn("kws-cnn", 16, 16, &[3, 8, 16, 32], 10, DataWidth::Int8);
    println!(
        "workload `{}`: {} kernels, {:.1} M ops",
        workload.name,
        workload.len(),
        workload.total_ops() as f64 / 1e6
    );

    // 4. Schedule across deadlines and validate on the simulator.
    let medea = Medea::new(&platform, &profiles, &model);
    for ms in [20.0, 50.0, 250.0] {
        match medea.schedule(&workload, Time::from_ms(ms)) {
            Ok(s) => {
                let r = simulate(&workload, &platform, &model, &s);
                println!(
                    "deadline {ms:>5.0} ms -> active {:>6.2} ms, energy {:>7.1} uJ, \
                     nmc kernels: {}, sim deadline met: {}",
                    s.active_time().as_ms(),
                    s.active_energy().as_uj(),
                    s.decisions.iter().filter(|d| d.pe == PeId(1)).count(),
                    r.deadline_met,
                );
            }
            Err(e) => println!("deadline {ms:>5.0} ms -> {e}"),
        }
    }
}
