//! Scratch probe for calibration (not part of the public example set).
use medea::config::estimator::{Estimator, TilingPolicy};
use medea::ir::tsd::{tsd_core, TsdParams};
use medea::platform::heeptimize::{heeptimize, CARUS, CGRA, CPU};
use medea::profile::characterize;
use medea::tiling::modes::TilingMode;
use medea::tiling::plan::plan_kernel;
use medea::timing::cycle_model::CycleModel;

fn main() {
    let platform = heeptimize();
    let model = CycleModel::heeptimize();
    let profiles = characterize(&platform, &model);
    let est = Estimator::new(&platform, &profiles, &model);
    let est_db = Estimator::new(&platform, &profiles, &model).with_policy(TilingPolicy::ForceDouble);
    let w = tsd_core(&TsdParams::default());

    let mut traffic = 0u64;
    let mut compute = 0u64;
    let mut total_ad = 0u64;
    let mut total_db = 0u64;
    let mut sb_count = 0;
    for k in w.kernels() {
        // best PE at min-V by energy among supported
        let mut best: Option<(medea::platform::PeId, u64, TilingMode)> = None;
        for pe in [CPU, CGRA, CARUS] {
            if let Some((mode, cyc)) = est.best_mode(pe, k) {
                if best.map(|(_, c, _)| cyc.raw() < c).unwrap_or(true) {
                    best = Some((pe, cyc.raw(), mode));
                }
            }
        }
        let (pe, cyc, mode) = best.unwrap();
        total_ad += cyc;
        if mode == TilingMode::SingleBuffer && pe != CPU {
            sb_count += 1;
        }
        if let Some((_, cyc_db)) = est_db.best_mode(pe, k) {
            total_db += cyc_db.raw();
        }
        compute += est.processing_cycles(pe, k).map(|c| c.raw()).unwrap_or(0);
        if pe != CPU {
            let lm = platform.pe(pe).lm.unwrap();
            let c = platform.constraints.get(pe, k.ty).unwrap();
            if let Some(p) = plan_kernel(k, lm, c.max_dim) {
                traffic += p.traffic_in.raw() + p.traffic_out.raw();
            }
        }
    }
    println!("total adaptive cycles (fastest-PE): {total_ad} ({:.1} ms @122MHz)", total_ad as f64 / 122e6 * 1e3);
    println!("total forced-db cycles:             {total_db} (+{:.2} %)", (total_db as f64 / total_ad as f64 - 1.0) * 100.0);
    println!("processing-only cycles:             {compute} ({:.1} % of total)", compute as f64 / total_ad as f64 * 100.0);
    println!("accelerator traffic: {:.1} KB", traffic as f64 / 1024.0);
    println!("sb-mode accelerator kernels: {sb_count}/{}", w.len());
}
