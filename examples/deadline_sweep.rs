//! Deadline sweep: how MEDEA trades energy for slack across the whole
//! feasible deadline range (the paper's §5.1 study, densified), plus the
//! per-feature savings at each point.
//!
//! ```sh
//! cargo run --release --example deadline_sweep
//! ```

use medea::exp::ExpContext;
use medea::manager::medea::MedeaFeatures;
use medea::util::table::{fnum, Table};
use medea::util::units::Time;

fn main() {
    let ctx = ExpContext::paper();
    let medea = ctx.medea();

    // Find the feasibility edge first.
    let mut lo = 1.0;
    let mut hi = 100.0;
    while hi - lo > 0.5 {
        let mid = 0.5 * (lo + hi);
        if medea.schedule(&ctx.workload, Time::from_ms(mid)).is_ok() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    println!("feasibility edge: ~{hi:.1} ms (fastest possible schedule)\n");

    let mut t = Table::new(&[
        "Deadline (ms)",
        "Active (ms)",
        "E_active (uJ)",
        "E_total (uJ)",
        "KerDVFS save",
        "AdapTile save",
    ]);
    let deadlines = [hi.ceil(), 50.0, 75.0, 100.0, 150.0, 200.0, 300.0, 500.0, 1000.0];
    for &ms in deadlines.iter() {
        let d = Time::from_ms(ms);
        let Ok(full) = medea.schedule(&ctx.workload, d) else {
            continue;
        };
        // Near the feasibility edge an ablated MEDEA may be infeasible —
        // itself a finding (the features buy feasibility, not just energy).
        let saving = |feats: MedeaFeatures| -> String {
            match ctx.medea_with(feats).schedule(&ctx.workload, d) {
                Ok(abl) => format!(
                    "{:.1} %",
                    (1.0 - full.total_energy(&ctx.platform).raw()
                        / abl.total_energy(&ctx.platform).raw())
                        * 100.0
                ),
                Err(_) => "infeasible".into(),
            }
        };
        t.row(vec![
            fnum(ms, 0),
            fnum(full.active_time().as_ms(), 1),
            fnum(full.active_energy().as_uj(), 0),
            fnum(full.total_energy(&ctx.platform).as_uj(), 0),
            saving(MedeaFeatures::without_kernel_dvfs()),
            saving(MedeaFeatures::without_adaptive_tiling()),
        ]);
    }
    println!("{}", t.to_text());
    println!("note: E_total includes sleep energy over the full deadline window,");
    println!("which is why very relaxed deadlines cost more total energy again");
    println!("(the paper's §5.1 observation about idle power prominence).");
}
