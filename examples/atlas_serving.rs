//! Atlas-backed serving: precompute the schedule atlas, then push a burst
//! of mixed-deadline traffic (including infeasible requests) through the
//! multi-worker pool. Runs without AOT artifacts — responses are
//! schedule-only, which is exactly the serving-path machinery this example
//! demonstrates.
//!
//! ```sh
//! cargo run --release --example atlas_serving
//! ```

use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::serve::{AtlasConfig, PoolConfig, Rejection, ScheduleAtlas, ServePool};
use medea::util::units::Time;
use std::time::Instant;

fn main() {
    // 1. Design time: sweep the feasible deadline range once.
    let ctx = ExpContext::paper();
    let t0 = Instant::now();
    let atlas = ScheduleAtlas::build(&ctx.medea(), &ctx.workload, &AtlasConfig::default())
        .expect("atlas build");
    println!(
        "atlas: {} knots in {:.0} ms, floor {:.1} ms (min makespan {:.1} ms)",
        atlas.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        atlas.floor().as_ms(),
        atlas.min_makespan.as_ms()
    );
    for k in atlas.knots().iter().take(6) {
        println!(
            "  knot {:>7.1} ms -> active {:>6.2} ms, {:>7.1} uJ",
            k.deadline.as_ms(),
            k.schedule.active_time().as_ms(),
            k.schedule.active_energy().as_uj()
        );
    }
    if atlas.len() > 6 {
        println!("  ... ({} more)", atlas.len() - 6);
    }

    // 2. Serve time: share the atlas across a worker pool and burst-submit.
    let floor_ms = atlas.floor().as_ms();
    let pool = ServePool::start_with_atlas(
        PoolConfig {
            workers: 4,
            ..PoolConfig::default()
        },
        atlas,
    )
    .expect("start pool");

    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let deadlines_ms = [
        floor_ms * 0.6, // infeasible: shed with a typed rejection
        floor_ms * 1.2,
        100.0,
        200.0,
        1000.0,
    ];
    let mut tickets = Vec::new();
    for i in 0..40 {
        let d = Time::from_ms(deadlines_ms[i % deadlines_ms.len()]);
        match pool.submit(gen.next_window(), d) {
            Ok(t) => tickets.push(t),
            Err(Rejection::BelowFloor { requested, floor }) => println!(
                "request {i:>2}: shed ({:.1} ms below floor {:.1} ms)",
                requested.as_ms(),
                floor.as_ms()
            ),
            Err(other) => println!("request {i:>2}: shed ({other})"),
        }
    }
    for t in tickets {
        let out = t.wait().expect("serve");
        if out.window_index < 5 {
            println!(
                "request {:>2}: knot {:>6.1} ms, sim {:>6.2} ms / {:>6.1} uJ, met={}, host {:?}",
                out.window_index,
                out.knot_deadline.as_ms(),
                out.sim.active_time.as_ms(),
                out.sim.total_energy().as_uj(),
                out.sim.deadline_met,
                out.host_latency
            );
        }
    }

    // 3. Cross-worker metrics.
    let metrics = pool.shutdown();
    println!("\n{}", metrics.summary());
}
