//! Quickstart: characterize HEEPtimize, schedule the TSD workload under a
//! 200 ms deadline, and validate the schedule on the event simulator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use medea::exp::ExpContext;
use medea::sim::replay::simulate;
use medea::util::units::Time;

fn main() {
    // 1. Platform + characterization profiles + workload (the paper's §4
    //    setup). `ExpContext::paper()` bundles:
    //      * the HEEPtimize platform preset (CPU + CGRA + Carus NMC),
    //      * the characterization campaign (timing S_c + power S_P),
    //      * the TSD transformer core decomposed into 164 kernels.
    let ctx = ExpContext::paper();
    println!(
        "platform `{}`: {} PEs, V-F {:?}, workload `{}` with {} kernels / {:.1} M ops",
        ctx.platform.name,
        ctx.platform.pes.len(),
        ctx.platform
            .vf
            .points()
            .iter()
            .map(|p| p.label())
            .collect::<Vec<_>>(),
        ctx.workload.name,
        ctx.workload.len(),
        ctx.workload.total_ops() as f64 / 1e6,
    );

    // 2. Run MEDEA: minimize energy subject to the 200 ms deadline.
    let deadline = Time::from_ms(200.0);
    let schedule = ctx
        .medea()
        .schedule(&ctx.workload, deadline)
        .expect("200 ms is feasible on HEEPtimize");
    println!(
        "\nMEDEA schedule: active {:.1} ms (deadline {:.0} ms), energy {:.0} uJ, optimal={}",
        schedule.active_time().as_ms(),
        deadline.as_ms(),
        schedule.active_energy().as_uj(),
        schedule.optimal,
    );

    // 3. Where did the kernels go?
    println!("\nassignments (PE @ V-F -> kernel count):");
    for ((pe, vf), n) in schedule.assignment_histogram() {
        println!(
            "  {:>6} @ {:>13} -> {n}",
            ctx.platform.pe(pe).name,
            ctx.platform.vf.get(vf).label()
        );
    }

    // 4. Independent validation: replay on the discrete-event simulator.
    let report = simulate(&ctx.workload, &ctx.platform, &ctx.model, &schedule);
    println!(
        "\nsimulator: active {:.1} ms, energy {:.0} uJ, {} events, deadline met: {}",
        report.active_time.as_ms(),
        report.active_energy.as_uj(),
        report.events,
        report.deadline_met,
    );
}
