//! End-to-end seizure-detection driver — the full three-layer system on a
//! realistic workload:
//!
//!   synthetic EEG stream → Rust FFT frontend → MEDEA schedules the TSD
//!   transformer for the deadline → the discrete-event simulator replays
//!   the schedule (on-device time/energy, deadline check) → the PJRT
//!   runtime executes the AOT-compiled TSD artifact for the functional
//!   prediction → headline energy table vs the baselines.
//!
//! Requires artifacts: `make artifacts` first. Recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example seizure_detection
//! ```

use medea::baselines::coarse_grain_app_dvfs;
use medea::coordinator::service::{Coordinator, Request};
use medea::eeg::synth::{EegGenerator, SynthConfig};
use medea::exp::ExpContext;
use medea::runtime::artifacts::ArtifactManifest;
use medea::sim::replay::simulate;
use medea::util::table::{fnum, Table};
use medea::util::units::Time;

fn main() {
    let artifact_dir = ArtifactManifest::default_dir();
    if !artifact_dir.join("manifest.json").exists() {
        eprintln!("artifacts not found in {artifact_dir:?}; run `make artifacts` first");
        std::process::exit(1);
    }

    let n_windows = 24usize;
    let deadline = Time::from_ms(200.0);
    println!(
        "serving {n_windows} EEG windows (16 ch x 6 s) at a {:.0} ms deadline\n",
        deadline.as_ms()
    );

    // --- the service loop -------------------------------------------------
    let coord = Coordinator::start(&artifact_dir).expect("start coordinator");
    let mut gen = EegGenerator::new(SynthConfig::default(), 42);
    let mut correct = 0usize;
    let mut truths = Vec::new();
    for _ in 0..n_windows {
        let window = gen.next_window();
        let truth = window.seizure;
        truths.push(truth);
        let out = coord.infer(Request { window, deadline }).expect("inference");
        let ok = out.prediction.seizure == truth;
        correct += ok as usize;
        println!(
            "window {:>3}  truth={:<10}  pred={:<10}{}  on-device: {:>6.1} ms / {:>5.0} uJ (met={})  host {:?}",
            out.window_index,
            label(truth),
            label(out.prediction.seizure),
            if ok { "  " } else { " *" },
            out.sim.active_time.as_ms(),
            out.sim.total_energy().as_uj(),
            out.sim.deadline_met,
            out.host_latency,
        );
    }
    let metrics = coord.shutdown();
    println!("\n{}", metrics.summary());
    println!(
        "agreement with synthetic labels: {correct}/{n_windows} (untrained synthetic weights — \
         functional-path validation, not a clinical claim)\n"
    );

    // --- the headline energy table ----------------------------------------
    println!("headline: MEDEA vs CoarseGrain(AppDVFS) total energy per window");
    let ctx = ExpContext::paper();
    let mut t = Table::new(&["Deadline (ms)", "CoarseGrain (uJ)", "MEDEA (uJ)", "Saving"]);
    for ms in ExpContext::DEADLINES_MS {
        let d = Time::from_ms(ms);
        let cg = coarse_grain_app_dvfs(&ctx.workload, &ctx.platform, &ctx.profiles, &ctx.model, d)
            .unwrap();
        let me = ctx.medea().schedule(&ctx.workload, d).unwrap();
        let e_cg = simulate(&ctx.workload, &ctx.platform, &ctx.model, &cg)
            .total_energy()
            .as_uj();
        let e_me = simulate(&ctx.workload, &ctx.platform, &ctx.model, &me)
            .total_energy()
            .as_uj();
        t.row(vec![
            fnum(ms, 0),
            fnum(e_cg, 0),
            fnum(e_me, 0),
            format!("{:.1} %", (1.0 - e_me / e_cg) * 100.0),
        ]);
    }
    println!("{}", t.to_text());
}

fn label(seizure: bool) -> &'static str {
    if seizure {
        "seizure"
    } else {
        "background"
    }
}
